//! MG — NPB multi-grid kernel (structured grids, paper Fig. 2/4).
//!
//! A V-cycle solver for the periodic 3-D Poisson problem `-∇²u = v` with a
//! scaled-Jacobi smoother, piecewise-constant prolongation and 8-child
//! averaging restriction. Four code regions per main iteration, matching
//! the paper's MG abstraction (R1–R4 in Fig. 2a):
//!
//! * R0 `resid`    — fine-grid residual `r = v − A·u`
//! * R1 `restrict` — push residuals down the grid hierarchy
//! * R2 `coarse`   — coarse-grid corrections + prolongation up
//! * R3 `smooth`   — apply the accumulated correction to `u`
//!
//! Candidates: `u` (solution) and `r` (residual hierarchy) — exactly the
//! objects Fig. 4a studies. `v` (the rhs) is deterministic init data and
//! is restored by re-initialization on restart. Like the paper's MG, `r`
//! is recomputed from `u` every iteration, so persisting `u` matters and
//! persisting `r` barely does (Observation 2).
//!
//! f32 numerics so the PJRT path (`mg_vcycle` artifact, Pallas stencil
//! kernel) is interchangeable with the native kernel.

use std::sync::OnceLock;

use super::{AppCore, Golden, RegionSpec};
use crate::runtime::StepEngine;
use crate::sim::{Buf, Env, ObjSpec, Signal};
use crate::util::rng::Rng;

/// Grid edge (power of two). Levels halve until [`Mg::COARSEST`].
const DIM: usize = 32;
const LEVELS: usize = 4;
/// Jacobi relaxation weight (1/diagonal of the 7-pt operator).
const OMEGA: f32 = 1.0 / 6.0;

pub struct Mg {
    pub iters: u64,
    /// Verification slack: accept a final residual within this factor of
    /// golden (NPB-style epsilon; leaves a few V-cycles of margin).
    pub tol_factor: f64,
    pub seed: u64,
    gold: OnceLock<Golden>,
}

impl Default for Mg {
    fn default() -> Mg {
        Mg {
            iters: 14,
            tol_factor: crate::util::env_f64("EC_TOL_MG", 3e-4),
            seed: 0x6D67,
            gold: OnceLock::new(),
        }
    }
}

pub struct St {
    /// Fine-grid solution (candidate).
    u: Buf,
    /// Residual hierarchy, all levels concatenated (candidate).
    r: Buf,
    /// Fine-grid rhs (re-initialized on restart).
    v: Buf,
    /// Correction hierarchy (scratch, recomputed every iteration).
    z: Buf,
    it: Buf,
}

impl Mg {
    /// Nodes at level `l` (level 0 = finest).
    fn n_at(l: usize) -> usize {
        let d = DIM >> l;
        d * d * d
    }

    /// Offset of level `l` within the hierarchy arrays.
    fn off(l: usize) -> usize {
        (0..l).map(Self::n_at).sum()
    }

    fn hier_len() -> usize {
        Self::off(LEVELS)
    }

    #[inline]
    fn idx(d: usize, x: usize, y: usize, z: usize) -> usize {
        (z * d + y) * d + x
    }

    /// Fine-grid 7-pt operator applied at one node (periodic).
    #[inline]
    fn apply_a<E: Env>(
        env: &mut E,
        u: Buf,
        base: usize,
        d: usize,
        x: usize,
        y: usize,
        z: usize,
    ) -> Result<f32, Signal> {
        let m = d - 1; // dims are powers of two
        let c = env.ldf(u, base + Self::idx(d, x, y, z))?;
        let xm = env.ldf(u, base + Self::idx(d, (x.wrapping_sub(1)) & m, y, z))?;
        let xp = env.ldf(u, base + Self::idx(d, (x + 1) & m, y, z))?;
        let ym = env.ldf(u, base + Self::idx(d, x, (y.wrapping_sub(1)) & m, z))?;
        let yp = env.ldf(u, base + Self::idx(d, x, (y + 1) & m, z))?;
        let zm = env.ldf(u, base + Self::idx(d, x, y, (z.wrapping_sub(1)) & m))?;
        let zp = env.ldf(u, base + Self::idx(d, x, y, (z + 1) & m))?;
        Ok(6.0 * c - (xm + xp + ym + yp + zm + zp))
    }

    /// Weighted-Jacobi refinement of `A·z = r` at level `l` (in place on
    /// the `z` hierarchy).
    fn jacobi_refine<E: Env>(
        env: &mut E,
        st: &St,
        l: usize,
        sweeps: usize,
    ) -> Result<(), Signal> {
        let d = DIM >> l;
        let b = Self::off(l);
        for _ in 0..sweeps {
            for z in 0..d {
                for y in 0..d {
                    for x in 0..d {
                        let i = b + Self::idx(d, x, y, z);
                        let a = Self::apply_a(env, st.z, b, d, x, y, z)?;
                        let rr = env.ldf(st.r, i)?;
                        let zz = env.ldf(st.z, i)?;
                        env.stf(st.z, i, zz + OMEGA * (rr - a))?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Trilinear (cell-centered) prolongation: interpolate the coarse
    /// field at fine node (x,y,z) with 3/4–1/4 weights per dimension,
    /// periodic. Good enough interpolation for textbook V-cycle rates
    /// (piecewise-constant prolongation stalls the cycle).
    #[inline]
    fn prolong_at<E: Env>(
        env: &mut E,
        zb: Buf,
        bc: usize,
        dc: usize,
        x: usize,
        y: usize,
        z: usize,
    ) -> Result<f32, Signal> {
        let m = dc - 1;
        let part = |k: usize| -> (usize, usize) {
            let p = k / 2;
            let n = if k % 2 == 1 { (p + 1) & m } else { p.wrapping_sub(1) & m };
            (p, n)
        };
        let (px, nx) = part(x);
        let (py, ny) = part(y);
        let (pz, nz) = part(z);
        let mut s = 0.0f32;
        for (cx, wx) in [(px, 0.75f32), (nx, 0.25)] {
            for (cy, wy) in [(py, 0.75f32), (ny, 0.25)] {
                for (cz, wz) in [(pz, 0.75f32), (nz, 0.25)] {
                    s += wx * wy * wz * env.ldf(zb, bc + Self::idx(dc, cx, cy, cz))?;
                }
            }
        }
        Ok(s)
    }

    /// Residual on the current state, computed from scratch (verification).
    fn residual_norm<E: Env>(&self, env: &mut E, st: &St) -> Result<f64, Signal> {
        let d = DIM;
        let mut s = 0.0f64;
        for z in 0..d {
            for y in 0..d {
                for x in 0..d {
                    let a = Self::apply_a(env, st.u, 0, d, x, y, z)?;
                    let v = env.ldf(st.v, Self::idx(d, x, y, z))?;
                    let rr = (v - a) as f64;
                    s += rr * rr;
                }
            }
        }
        Ok(s.sqrt())
    }
}

impl AppCore for Mg {
    type St = St;

    fn name(&self) -> &'static str {
        "mg"
    }

    fn description(&self) -> &'static str {
        "NPB MG: V-cycle multigrid for periodic 3-D Poisson"
    }

    fn region_specs(&self) -> Vec<RegionSpec> {
        vec![
            RegionSpec::l("resid"),
            RegionSpec::l("restrict"),
            RegionSpec::l("coarse"),
            RegionSpec::l("smooth"),
        ]
    }

    fn iters(&self) -> u64 {
        self.iters
    }

    fn build<E: Env>(&self, env: &mut E) -> Result<St, Signal> {
        let n = Self::n_at(0);
        let h = Self::hier_len();
        let u = env.alloc(ObjSpec::f32("u", n, true));
        let r = env.alloc(ObjSpec::f32("r", h, true));
        let v = env.alloc(ObjSpec::f32("v", n, false));
        let z = env.alloc(ObjSpec::f32("z", h, false));
        let it = env.alloc(ObjSpec::i64("it", 1, true));
        for i in 0..n {
            env.stf(u, i, 0.0)?;
            env.stf(v, i, 0.0)?;
        }
        for i in 0..h {
            env.stf(r, i, 0.0)?;
            env.stf(z, i, 0.0)?;
        }
        // NPB-style rhs: ±1 charges at random nodes (zero mean, so the
        // periodic problem is solvable).
        let mut rng = Rng::new(self.seed);
        for s in 0..16 {
            let i = rng.index(n);
            env.stf(v, i, if s % 2 == 0 { 1.0 } else { -1.0 })?;
        }
        env.sti(it, 0, 0)?;
        Ok(St { u, r, v, z, it })
    }

    fn step<E: Env>(&self, env: &mut E, st: &St, _it: u64) -> Result<(), Signal> {
        let d0 = DIM;

        // R0: fine residual r0 = v - A u
        env.region(0)?;
        for z in 0..d0 {
            for y in 0..d0 {
                for x in 0..d0 {
                    let a = Self::apply_a(env, st.u, 0, d0, x, y, z)?;
                    let v = env.ldf(st.v, Self::idx(d0, x, y, z))?;
                    env.stf(st.r, Self::idx(d0, x, y, z), v - a)?;
                }
            }
        }

        // R1: restrict residuals down the hierarchy (8-child average)
        env.region(1)?;
        for l in 1..LEVELS {
            let df = DIM >> (l - 1);
            let dc = DIM >> l;
            let bf = Self::off(l - 1);
            let bc = Self::off(l);
            for z in 0..dc {
                for y in 0..dc {
                    for x in 0..dc {
                        let mut s = 0.0f32;
                        for dz in 0..2 {
                            for dy in 0..2 {
                                for dx in 0..2 {
                                    s += env.ldf(
                                        st.r,
                                        bf + Self::idx(df, 2 * x + dx, 2 * y + dy, 2 * z + dz),
                                    )?;
                                }
                            }
                        }
                        env.stf(st.r, bc + Self::idx(dc, x, y, z), s * 0.125)?;
                    }
                }
            }
        }

        // R2: coarse corrections — at each level solve A·z ≈ r with a few
        // Jacobi refinements seeded by the prolonged next-coarser
        // correction (a genuine V-cycle upstroke).
        env.region(2)?;
        {
            // coarsest: z = ω r, then refine
            let l = LEVELS - 1;
            let dc = DIM >> l;
            let bc = Self::off(l);
            for i in 0..dc * dc * dc {
                let rr = env.ldf(st.r, bc + i)?;
                env.stf(st.z, bc + i, OMEGA * rr)?;
            }
            Self::jacobi_refine(env, st, l, 3)?;
            // walk up to level 1
            for l in (1..LEVELS - 1).rev() {
                let df = DIM >> l;
                let bc = Self::off(l + 1);
                let bf = Self::off(l);
                let dc = df / 2;
                for z in 0..df {
                    for y in 0..df {
                        for x in 0..df {
                            let zc = Self::prolong_at(env, st.z, bc, dc, x, y, z)?;
                            env.stf(st.z, bf + Self::idx(df, x, y, z), zc)?;
                        }
                    }
                }
                Self::jacobi_refine(env, st, l, 2)?;
            }
        }

        // R3: apply correction to the fine solution + one fine smoothing
        // pass.
        env.region(3)?;
        {
            let b1 = Self::off(1);
            let d1 = DIM / 2;
            for z in 0..d0 {
                for y in 0..d0 {
                    for x in 0..d0 {
                        let i = Self::idx(d0, x, y, z);
                        let zc = Self::prolong_at(env, st.z, b1, d1, x, y, z)?;
                        let r0 = env.ldf(st.r, i)?;
                        let u0 = env.ldf(st.u, i)?;
                        env.stf(st.u, i, u0 + zc + OMEGA * r0)?;
                    }
                }
            }
            // Fine post-smoothing: u += ω (v − A u).
            for z in 0..d0 {
                for y in 0..d0 {
                    for x in 0..d0 {
                        let i = Self::idx(d0, x, y, z);
                        let a = Self::apply_a(env, st.u, 0, d0, x, y, z)?;
                        let v = env.ldf(st.v, i)?;
                        let u0 = env.ldf(st.u, i)?;
                        env.stf(st.u, i, u0 + OMEGA * (v - a))?;
                    }
                }
            }
        }
        Ok(())
    }

    fn step_fast(
        &self,
        env: &mut crate::sim::RawEnv,
        st: &St,
        it: u64,
        engine: &mut dyn StepEngine,
    ) -> Result<(), Signal> {
        if !engine.supports("mg_vcycle") {
            return self.step(env, st, it);
        }
        // PJRT path: u' = vcycle(u, v); r0 is returned too and written back
        // so the persisted-state layout matches the native path.
        let u = env.f32_slice(st.u).to_vec();
        let v = env.f32_slice(st.v).to_vec();
        let outs = engine
            .call_f32("mg_vcycle", &[&u, &v])
            .map_err(|_| Signal::Interrupt)?;
        let n = Self::n_at(0);
        env.f32_slice_mut(st.u).copy_from_slice(&outs[0][..n]);
        env.f32_slice_mut(st.r)[..n].copy_from_slice(&outs[1][..n]);
        Ok(())
    }

    fn metric<E: Env>(&self, env: &mut E, st: &St) -> Result<f64, Signal> {
        self.residual_norm(env, st)
    }

    fn accept(&self, metric: f64, golden: &Golden) -> bool {
        // NPB-style strict band: the final residual must match the
        // reference run within tol_factor relative (two-sided — a
        // *different* residual signals contaminated recomputation even if
        // smaller).
        metric.is_finite()
            && (metric - golden.metric).abs() <= self.tol_factor * golden.metric.abs()
    }

    fn iter_buf(st: &St) -> Buf {
        st.it
    }

    fn golden_cell(&self) -> &OnceLock<Golden> {
        &self.gold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::CrashApp;
    use crate::sim::RawEnv;

    #[test]
    fn vcycles_converge() {
        let mg = Mg::default();
        let mut raw = RawEnv::new();
        let st = mg.build(&mut raw).unwrap();
        let r0 = mg.residual_norm(&mut raw, &st).unwrap();
        for it in 0..mg.iters {
            mg.step(&mut raw, &st, it).unwrap();
        }
        let rn = mg.residual_norm(&mut raw, &st).unwrap();
        assert!(
            rn < r0 / 50.0,
            "V-cycles must reduce the residual: {r0} -> {rn}"
        );
    }

    #[test]
    fn residual_decreases_monotonically() {
        let mg = Mg::default();
        let mut raw = RawEnv::new();
        let st = mg.build(&mut raw).unwrap();
        let mut prev = mg.residual_norm(&mut raw, &st).unwrap();
        for it in 0..6 {
            mg.step(&mut raw, &st, it).unwrap();
            let rn = mg.residual_norm(&mut raw, &st).unwrap();
            assert!(rn < prev, "iter {it}: {rn} !< {prev}");
            prev = rn;
        }
    }

    #[test]
    fn golden_accepts_itself() {
        let mg = Mg::default();
        let g = mg.golden();
        assert!(mg.accept(g.metric, &g));
        assert!(!mg.accept(g.metric * 1e4, &g));
    }

    #[test]
    fn footprint_exceeds_mini_llc() {
        let mg = Mg::default();
        let cfg = crate::sim::SimConfig::mini();
        let mut env = crate::sim::SimEnv::new(&cfg, mg.regions().len());
        mg.build(&mut env).unwrap();
        assert!(env.reg.footprint() > cfg.l3.size, "paper requires footprint >> LLC");
    }
}

//! DCG — distributed CG: the CSR conjugate-gradient kernel of
//! [`cg`](super::cg) split across `R` simulated ranks with row-block
//! (j-plane) partitioning.
//!
//! Each rank owns a contiguous block of grid planes: its own CSR slice of
//! the 5-point Laplacian (column indices remapped to rank-local `p`
//! addressing), its own Krylov block `x, r, p, q`, a replicated scalar
//! carrier `sc` (the global ρ) and a per-rank loop bookmark `it`.
//! Communication is explicit and deterministic:
//!
//! * **halo exchange** before SpMV — each rank sends its first/last owned
//!   plane of `p` to its neighbors ([`halo_send`] → [`route_halos`] →
//!   [`halo_recv`]);
//! * **allreduce** for the two dot products — rank-order left fold from
//!   `0.0f32`, so the reduction order is fixed and replay is
//!   bit-reproducible.
//!
//! At `ranks == 1` the app allocates the exact object set of `cg` under
//! the same names and emits a bit-identical access stream (the halo phases
//! are empty, the folds reduce over one partial), so single-rank DCG
//! campaigns are record-identical to native CG — test-enforced in
//! `rust/tests/rank.rs`. At `ranks > 1` every object name carries a
//! `.r<k>` suffix so the composite registry stays unambiguous.
//!
//! The per-rank kernels are `pub` and free-standing: `easycrash::rank`
//! drives them in lockstep over one `SimEnv` *per rank* for multi-rank
//! crash campaigns with partial-failure recovery ([`Dcg::assisted_rebuild`]
//! is the survivors-recompute-the-lost-block path of the NVRAM-solvers
//! recovery mode).

use std::sync::OnceLock;

use super::{AppCore, Golden, RegionSpec};
use crate::sim::{Buf, Env, ObjSpec, Signal};

/// Grid edge: the global problem is EDGE² unknowns (same as `cg`).
pub const EDGE: usize = 96;
const N: usize = EDGE * EDGE;
/// Bulk-API chunk for the dense vector phases, matching `cg`.
const CHUNK: usize = 256;
/// Rank-count ceiling (validated by `ExperimentSpec` as well).
pub const MAX_RANKS: usize = 8;
/// Code regions per iteration — the same six CG phases as `cg`.
pub const NUM_REGIONS: usize = 6;

/// Object names per rank. Rank 1 uses the plain `cg` names so the R=1
/// layout (and therefore every plan string) is interchangeable with the
/// native app; multi-rank builds suffix every name with the rank id.
const PLAIN: [&str; 9] = [
    "vals", "cols", "rowptr", "x", "r", "p", "q", "sc", "it",
];
static RANK_NAMES: [[&str; 9]; MAX_RANKS] = [
    ["vals.r0", "cols.r0", "rowptr.r0", "x.r0", "r.r0", "p.r0", "q.r0", "sc.r0", "it.r0"],
    ["vals.r1", "cols.r1", "rowptr.r1", "x.r1", "r.r1", "p.r1", "q.r1", "sc.r1", "it.r1"],
    ["vals.r2", "cols.r2", "rowptr.r2", "x.r2", "r.r2", "p.r2", "q.r2", "sc.r2", "it.r2"],
    ["vals.r3", "cols.r3", "rowptr.r3", "x.r3", "r.r3", "p.r3", "q.r3", "sc.r3", "it.r3"],
    ["vals.r4", "cols.r4", "rowptr.r4", "x.r4", "r.r4", "p.r4", "q.r4", "sc.r4", "it.r4"],
    ["vals.r5", "cols.r5", "rowptr.r5", "x.r5", "r.r5", "p.r5", "q.r5", "sc.r5", "it.r5"],
    ["vals.r6", "cols.r6", "rowptr.r6", "x.r6", "r.r6", "p.r6", "q.r6", "sc.r6", "it.r6"],
    ["vals.r7", "cols.r7", "rowptr.r7", "x.r7", "r.r7", "p.r7", "q.r7", "sc.r7", "it.r7"],
];

/// The base object names of one rank (plain at R=1, suffixed otherwise).
pub fn rank_object_names(ranks: usize, k: usize) -> &'static [&'static str; 9] {
    assert!(k < ranks && ranks >= 1 && ranks <= MAX_RANKS);
    if ranks == 1 {
        &PLAIN
    } else {
        &RANK_NAMES[k]
    }
}

/// Planes owned by rank `k` of `ranks`: `[lo, hi)` j-plane indices.
/// Contiguous, covering, and balanced to within one plane for any R.
pub fn plane_range(ranks: usize, k: usize) -> (usize, usize) {
    (k * EDGE / ranks, (k + 1) * EDGE / ranks)
}

/// Per-rank state: the nine objects plus the partition geometry.
#[derive(Clone, Copy)]
pub struct RankSt {
    pub vals: Buf,
    pub cols: Buf,
    pub rowptr: Buf,
    pub x: Buf,
    pub r: Buf,
    pub p: Buf,
    pub q: Buf,
    /// Scalar carrier: sc[0] = global ρ, replicated on every rank.
    pub sc: Buf,
    pub it: Buf,
    /// First owned j-plane.
    pub plane0: usize,
    /// Owned unknowns (planes × EDGE).
    pub n: usize,
    /// True iff a lower neighbor (rank k−1) exists.
    pub has_lo: bool,
    /// True iff an upper neighbor (rank k+1) exists.
    pub has_hi: bool,
}

impl RankSt {
    /// `p` slot of the halo plane received from the lower neighbor.
    pub fn halo_lo_base(&self) -> usize {
        self.n
    }
    /// `p` slot of the halo plane received from the upper neighbor.
    pub fn halo_hi_base(&self) -> usize {
        self.n + if self.has_lo { EDGE } else { 0 }
    }
}

/// One rank's outgoing halo planes of `p` (boundary planes it owns).
#[derive(Clone, Copy)]
pub struct HaloOut {
    pub lo: Option<[f32; EDGE]>,
    pub hi: Option<[f32; EDGE]>,
}

/// One rank's incoming halo planes (its neighbors' boundary planes).
#[derive(Clone, Copy)]
pub struct HaloIn {
    pub from_lo: Option<[f32; EDGE]>,
    pub from_hi: Option<[f32; EDGE]>,
}

/// Allocate and initialize one rank's objects. The allocation order and
/// the initialization access stream are exactly `cg::build` restricted to
/// the rank's rows, so R=1 reproduces the native app bit for bit.
pub fn build_rank<E: Env>(env: &mut E, ranks: usize, k: usize) -> Result<RankSt, Signal> {
    let names = rank_object_names(ranks, k);
    let (p_lo, p_hi) = plane_range(ranks, k);
    let n = (p_hi - p_lo) * EDGE;
    let has_lo = k > 0;
    let has_hi = k + 1 < ranks;
    let halo = if has_lo { EDGE } else { 0 } + if has_hi { EDGE } else { 0 };
    let nnz_max = 5 * n;

    let vals = env.alloc(ObjSpec::f32(names[0], nnz_max, false));
    let cols = env.alloc(ObjSpec::i64(names[1], nnz_max, false));
    let rowptr = env.alloc(ObjSpec::i64(names[2], n + 1, false));
    let x = env.alloc(ObjSpec::f32(names[3], n, true));
    let r = env.alloc(ObjSpec::f32(names[4], n, true));
    let p = env.alloc(ObjSpec::f32(names[5], n + halo, true));
    let q = env.alloc(ObjSpec::f32(names[6], n, true));
    let sc = env.alloc(ObjSpec::f32(names[7], 1, true));
    let it = env.alloc(ObjSpec::i64(names[8], 1, true));

    let rs = RankSt {
        vals,
        cols,
        rowptr,
        x,
        r,
        p,
        q,
        sc,
        it,
        plane0: p_lo,
        n,
        has_lo,
        has_hi,
    };
    build_matrix_rank(env, &rs)?;
    // x₀ = 0; b ≡ 1 ⇒ r₀ = b, p₀ = r₀; ρ₀ = global r·r = N on every rank.
    let zeros = vec![0.0f32; n];
    let ones = vec![1.0f32; n];
    env.st_slice_f32(x, 0, &zeros)?;
    env.st_slice_f32(r, 0, &ones)?;
    env.st_slice_f32(p, 0, &ones)?;
    env.st_slice_f32(q, 0, &zeros)?;
    env.stf(sc, 0, N as f32)?;
    env.sti(it, 0, 0)?;
    Ok(rs)
}

/// CSR slice of the 5-point Dirichlet Laplacian for the rank's rows, with
/// columns remapped to rank-local `p` addressing (halo slots for the
/// neighbor planes). Same per-row emission order as `cg::build_matrix`.
fn build_matrix_rank<E: Env>(env: &mut E, rs: &RankSt) -> Result<(), Signal> {
    let mut nz = 0usize;
    for lr in 0..rs.n {
        env.sti(rs.rowptr, lr, nz as i64)?;
        let gr = rs.plane0 * EDGE + lr;
        let (i, j) = (gr % EDGE, gr / EDGE);
        if j > 0 {
            let c = if lr >= EDGE {
                lr - EDGE
            } else {
                rs.halo_lo_base() + i
            };
            env.stf(rs.vals, nz, -1.0)?;
            env.sti(rs.cols, nz, c as i64)?;
            nz += 1;
        }
        if i > 0 {
            env.stf(rs.vals, nz, -1.0)?;
            env.sti(rs.cols, nz, (lr - 1) as i64)?;
            nz += 1;
        }
        env.stf(rs.vals, nz, 4.0)?;
        env.sti(rs.cols, nz, lr as i64)?;
        nz += 1;
        if i + 1 < EDGE {
            env.stf(rs.vals, nz, -1.0)?;
            env.sti(rs.cols, nz, (lr + 1) as i64)?;
            nz += 1;
        }
        if j + 1 < EDGE {
            let c = if lr + EDGE < rs.n {
                lr + EDGE
            } else {
                rs.halo_hi_base() + i
            };
            env.stf(rs.vals, nz, -1.0)?;
            env.sti(rs.cols, nz, c as i64)?;
            nz += 1;
        }
    }
    env.sti(rs.rowptr, rs.n, nz as i64)?;
    Ok(())
}

/// Read the rank's outgoing boundary planes of `p` (empty at R=1).
pub fn halo_send<E: Env>(env: &mut E, rs: &RankSt) -> Result<HaloOut, Signal> {
    let mut out = HaloOut { lo: None, hi: None };
    if rs.has_lo {
        let mut plane = [0.0f32; EDGE];
        env.ld_slice_f32(rs.p, 0, &mut plane)?;
        out.lo = Some(plane);
    }
    if rs.has_hi {
        let mut plane = [0.0f32; EDGE];
        env.ld_slice_f32(rs.p, rs.n - EDGE, &mut plane)?;
        out.hi = Some(plane);
    }
    Ok(out)
}

/// Deterministic halo routing: rank k receives rank k−1's `hi` plane and
/// rank k+1's `lo` plane. Pure data movement — no env accesses.
pub fn route_halos(outs: &[HaloOut]) -> Vec<HaloIn> {
    (0..outs.len())
        .map(|k| HaloIn {
            from_lo: if k > 0 { outs[k - 1].hi } else { None },
            from_hi: if k + 1 < outs.len() { outs[k + 1].lo } else { None },
        })
        .collect()
}

/// Write the received halo planes into the rank's `p` halo slots.
pub fn halo_recv<E: Env>(env: &mut E, rs: &RankSt, hin: &HaloIn) -> Result<(), Signal> {
    if let Some(plane) = hin.from_lo {
        env.st_slice_f32(rs.p, rs.halo_lo_base(), &plane)?;
    }
    if let Some(plane) = hin.from_hi {
        env.st_slice_f32(rs.p, rs.halo_hi_base(), &plane)?;
    }
    Ok(())
}

fn spmv_one_row<E: Env>(env: &mut E, rs: &RankSt, lr: usize, src: Buf) -> Result<f32, Signal> {
    let lo = env.ldi(rs.rowptr, lr)? as usize;
    let hi = env.ldi(rs.rowptr, lr + 1)? as usize;
    if hi > rs.vals.len as usize || lo > hi {
        return Err(Signal::Interrupt);
    }
    let mut s = 0.0f32;
    for nz in lo..hi {
        let c = env.ldi(rs.cols, nz)? as usize;
        let v = env.ldf(rs.vals, nz)?;
        s += v * env.ldf(src, c)?;
    }
    Ok(s)
}

/// R0 body: `q = A·p` over the rank's rows (halos must be current).
pub fn spmv_rank<E: Env>(env: &mut E, rs: &RankSt) -> Result<(), Signal> {
    for lr in 0..rs.n {
        let s = spmv_one_row(env, rs, lr, rs.p)?;
        env.stf(rs.q, lr, s)?;
    }
    Ok(())
}

/// R1 body: the rank's partial `p·q` plus its replica of ρ.
pub fn dot_pq_rank<E: Env>(env: &mut E, rs: &RankSt) -> Result<(f32, f32), Signal> {
    let mut a = [0.0f32; CHUNK];
    let mut b = [0.0f32; CHUNK];
    let mut pq = 0.0f32;
    let mut i = 0;
    while i < rs.n {
        let c = CHUNK.min(rs.n - i);
        env.ld_slice_f32(rs.p, i, &mut a[..c])?;
        env.ld_slice_f32(rs.q, i, &mut b[..c])?;
        for (&pv, &qv) in a[..c].iter().zip(&b[..c]) {
            pq += pv * qv;
        }
        i += c;
    }
    let rho = env.ldf(rs.sc, 0)?;
    Ok((pq, rho))
}

/// α from the allreduced `p·q` — the same guarded quotient as `cg`.
pub fn alpha_of(rho: f32, pq: f32) -> f32 {
    if pq.abs() > 1e-30 {
        rho / pq
    } else {
        0.0
    }
}

/// R2 body: `x += α·p` over the rank's block.
pub fn axpy_x_rank<E: Env>(env: &mut E, rs: &RankSt, alpha: f32) -> Result<(), Signal> {
    let mut a = [0.0f32; CHUNK];
    let mut b = [0.0f32; CHUNK];
    let mut i = 0;
    while i < rs.n {
        let c = CHUNK.min(rs.n - i);
        env.ld_slice_f32(rs.x, i, &mut a[..c])?;
        env.ld_slice_f32(rs.p, i, &mut b[..c])?;
        for (xv, &pv) in a[..c].iter_mut().zip(&b[..c]) {
            *xv += alpha * pv;
        }
        env.st_slice_f32(rs.x, i, &a[..c])?;
        i += c;
    }
    Ok(())
}

/// R3 body: `r −= α·q` over the rank's block.
pub fn axpy_r_rank<E: Env>(env: &mut E, rs: &RankSt, alpha: f32) -> Result<(), Signal> {
    let mut a = [0.0f32; CHUNK];
    let mut b = [0.0f32; CHUNK];
    let mut i = 0;
    while i < rs.n {
        let c = CHUNK.min(rs.n - i);
        env.ld_slice_f32(rs.r, i, &mut a[..c])?;
        env.ld_slice_f32(rs.q, i, &mut b[..c])?;
        for (rv, &qv) in a[..c].iter_mut().zip(&b[..c]) {
            *rv -= alpha * qv;
        }
        env.st_slice_f32(rs.r, i, &a[..c])?;
        i += c;
    }
    Ok(())
}

/// R4 body: the rank's partial `r·r`.
pub fn dot_rr_rank<E: Env>(env: &mut E, rs: &RankSt) -> Result<f32, Signal> {
    let mut a = [0.0f32; CHUNK];
    let mut rr = 0.0f32;
    let mut i = 0;
    while i < rs.n {
        let c = CHUNK.min(rs.n - i);
        env.ld_slice_f32(rs.r, i, &mut a[..c])?;
        for &v in &a[..c] {
            rr += v * v;
        }
        i += c;
    }
    Ok(rr)
}

/// R5 body: `β = ρ'/ρ; p = r + β·p` over the owned block (halo slots are
/// refreshed by the next exchange), then carry the allreduced ρ'.
pub fn update_p_rank<E: Env>(
    env: &mut E,
    rs: &RankSt,
    rho: f32,
    rho_new: f32,
) -> Result<(), Signal> {
    let beta = if rho.abs() > 1e-30 { rho_new / rho } else { 0.0 };
    let mut a = [0.0f32; CHUNK];
    let mut b = [0.0f32; CHUNK];
    let mut i = 0;
    while i < rs.n {
        let c = CHUNK.min(rs.n - i);
        env.ld_slice_f32(rs.r, i, &mut a[..c])?;
        env.ld_slice_f32(rs.p, i, &mut b[..c])?;
        for (pv, &rv) in b[..c].iter_mut().zip(&a[..c]) {
            *pv = rv + beta * *pv;
        }
        env.st_slice_f32(rs.p, i, &b[..c])?;
        i += c;
    }
    env.stf(rs.sc, 0, rho_new)?;
    Ok(())
}

pub struct Dcg {
    /// Simulated ranks (row-block partition of the EDGE×EDGE grid).
    pub ranks: usize,
    pub iters: u64,
    pub tol_factor: f64,
    gold: OnceLock<Golden>,
}

impl Default for Dcg {
    fn default() -> Dcg {
        Dcg::with_ranks(4)
    }
}

impl Dcg {
    pub fn with_ranks(ranks: usize) -> Dcg {
        assert!(
            (1..=MAX_RANKS).contains(&ranks),
            "dcg ranks must be 1..={MAX_RANKS}, got {ranks}"
        );
        Dcg {
            ranks,
            iters: 75,
            tol_factor: crate::util::env_f64("EC_TOL_CG", 2e-4),
            gold: OnceLock::new(),
        }
    }

    /// Assisted recovery (NVRAM-solvers style): rebuild the transient CG
    /// state from the surviving `x` alone. `p := x` is exchanged so every
    /// rank can recompute its true residual `r = b − A·x` (b ≡ 1), then
    /// the method restarts in the steepest-descent direction `p := r`
    /// with the allreduced ρ = r·r carried on every rank. Runs on any
    /// env; the classify path uses it on `RawEnv` after overlaying the
    /// crashed rank's NVM image.
    pub fn assisted_rebuild<E: Env>(&self, env: &mut E, st: &DcgSt) -> Result<(), Signal> {
        let mut a = [0.0f32; CHUNK];
        // p := x on the owned block of every rank.
        for rs in &st.ranks {
            let mut i = 0;
            while i < rs.n {
                let c = CHUNK.min(rs.n - i);
                env.ld_slice_f32(rs.x, i, &mut a[..c])?;
                env.st_slice_f32(rs.p, i, &a[..c])?;
                i += c;
            }
        }
        // Exchange so the halo planes hold the neighbors' x.
        let mut outs = Vec::with_capacity(st.ranks.len());
        for rs in &st.ranks {
            outs.push(halo_send(env, rs)?);
        }
        let ins = route_halos(&outs);
        for (rs, hin) in st.ranks.iter().zip(&ins) {
            halo_recv(env, rs, hin)?;
        }
        // r := b − A·x per owned row, then the restart direction p := r
        // and the recomputed global ρ on every rank.
        let mut rr = 0.0f32;
        for rs in &st.ranks {
            for lr in 0..rs.n {
                let ax = spmv_one_row(env, rs, lr, rs.p)?;
                env.stf(rs.r, lr, 1.0 - ax)?;
            }
            rr += dot_rr_rank(env, rs)?;
        }
        for rs in &st.ranks {
            let mut i = 0;
            while i < rs.n {
                let c = CHUNK.min(rs.n - i);
                env.ld_slice_f32(rs.r, i, &mut a[..c])?;
                env.st_slice_f32(rs.p, i, &a[..c])?;
                i += c;
            }
            env.stf(rs.sc, 0, rr)?;
        }
        Ok(())
    }
}

pub struct DcgSt {
    pub ranks: Vec<RankSt>,
}

impl AppCore for Dcg {
    type St = DcgSt;

    fn name(&self) -> &'static str {
        "dcg"
    }

    fn description(&self) -> &'static str {
        "distributed CG: row-block ranks over the 5-pt Poisson CSR \
         (halo exchange + allreduce, default 4 ranks)"
    }

    fn region_specs(&self) -> Vec<RegionSpec> {
        vec![
            RegionSpec::l("spmv"),
            RegionSpec::l("dot_pq"),
            RegionSpec::l("axpy_x"),
            RegionSpec::l("axpy_r"),
            RegionSpec::l("dot_rr"),
            RegionSpec::l("update_p"),
        ]
    }

    fn iters(&self) -> u64 {
        self.iters
    }

    fn build<E: Env>(&self, env: &mut E) -> Result<DcgSt, Signal> {
        let mut ranks = Vec::with_capacity(self.ranks);
        for k in 0..self.ranks {
            ranks.push(build_rank(env, self.ranks, k)?);
        }
        Ok(DcgSt { ranks })
    }

    fn step<E: Env>(&self, env: &mut E, st: &DcgSt, it: u64) -> Result<(), Signal> {
        // R0: exchange halos, then q = A p on every rank.
        env.region(0)?;
        let mut outs = Vec::with_capacity(st.ranks.len());
        for rs in &st.ranks {
            outs.push(halo_send(env, rs)?);
        }
        let ins = route_halos(&outs);
        for (rs, hin) in st.ranks.iter().zip(&ins) {
            halo_recv(env, rs, hin)?;
        }
        for rs in &st.ranks {
            spmv_rank(env, rs)?;
        }
        // R1: allreduce p·q (rank-order left fold), α = ρ / (p·q).
        env.region(1)?;
        let mut pq = 0.0f32;
        let mut rho = 0.0f32;
        for rs in &st.ranks {
            let (part, rho_k) = dot_pq_rank(env, rs)?;
            pq += part;
            rho = rho_k;
        }
        let alpha = alpha_of(rho, pq);
        // R2: x += α p.
        env.region(2)?;
        for rs in &st.ranks {
            axpy_x_rank(env, rs, alpha)?;
        }
        // R3: r −= α q.
        env.region(3)?;
        for rs in &st.ranks {
            axpy_r_rank(env, rs, alpha)?;
        }
        // R4: allreduce ρ' = r·r.
        env.region(4)?;
        let mut rho_new = 0.0f32;
        for rs in &st.ranks {
            rho_new += dot_rr_rank(env, rs)?;
        }
        // R5: β = ρ'/ρ; p = r + β p; carry ρ' on every rank.
        env.region(5)?;
        for rs in &st.ranks {
            update_p_rank(env, rs, rho, rho_new)?;
        }
        // Secondary bookmarks: the driver stores rank 0's (the app-level
        // iter_buf) after step; ranks 1.. mirror it here. Empty at R=1,
        // preserving bit-identity with `cg`.
        for rs in &st.ranks[1..] {
            env.sti(rs.it, 0, (it + 1) as i64)?;
        }
        Ok(())
    }

    fn metric<E: Env>(&self, env: &mut E, st: &DcgSt) -> Result<f64, Signal> {
        // ζ = Σx over ranks in rank-major order (cg's zeta at R=1).
        let mut s = 0.0f64;
        for rs in &st.ranks {
            for i in 0..rs.n {
                s += env.ldf(rs.x, i)? as f64;
            }
        }
        if !s.is_finite() {
            return Err(Signal::Interrupt);
        }
        Ok(s)
    }

    fn accept(&self, metric: f64, golden: &Golden) -> bool {
        metric.is_finite()
            && (metric - golden.metric).abs() <= self.tol_factor * golden.metric.abs()
    }

    fn iter_buf(st: &DcgSt) -> Buf {
        st.ranks[0].it
    }

    fn golden_cell(&self) -> &OnceLock<Golden> {
        &self.gold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::cg::Cg;
    use crate::apps::CrashApp;
    use crate::sim::RawEnv;

    #[test]
    fn r1_golden_is_bitwise_cg() {
        let g1 = Dcg::with_ranks(1).golden();
        let gc = Cg::default().golden();
        assert_eq!(g1.iters, gc.iters);
        assert_eq!(
            g1.metric.to_bits(),
            gc.metric.to_bits(),
            "R=1 dcg must reproduce cg exactly: {} vs {}",
            g1.metric,
            gc.metric
        );
    }

    #[test]
    fn partition_covers_grid_with_correct_halos() {
        for ranks in 1..=MAX_RANKS {
            let mut total = 0usize;
            let mut next_plane = 0usize;
            for k in 0..ranks {
                let (lo, hi) = plane_range(ranks, k);
                assert_eq!(lo, next_plane, "ranks={ranks} k={k}");
                assert!(hi > lo, "every rank owns at least one plane");
                next_plane = hi;
                total += (hi - lo) * EDGE;
            }
            assert_eq!(next_plane, EDGE);
            assert_eq!(total, EDGE * EDGE);
        }
        let mut raw = RawEnv::new();
        let st = Dcg::with_ranks(3).build(&mut raw).unwrap();
        assert!(!st.ranks[0].has_lo && st.ranks[0].has_hi);
        assert!(st.ranks[1].has_lo && st.ranks[1].has_hi);
        assert!(st.ranks[2].has_lo && !st.ranks[2].has_hi);
    }

    #[test]
    fn six_regions_like_cg() {
        assert_eq!(Dcg::default().regions().len(), 6);
    }

    #[test]
    fn r4_golden_is_finite_and_converges() {
        let d = Dcg::default();
        let g = d.golden();
        assert_eq!(g.iters, 75);
        assert!(g.metric.is_finite());
        // The multi-rank trajectory reassociates the f32 reductions, so it
        // is not bitwise cg — but it solves the same system and must land
        // in the same neighborhood.
        let g1 = Dcg::with_ranks(1).golden();
        let rel = (g.metric - g1.metric).abs() / g1.metric.abs().max(1.0);
        assert!(rel < 0.05, "R=4 drifted from R=1: {} vs {}", g.metric, g1.metric);
    }

    #[test]
    fn assisted_rebuild_restarts_cleanly() {
        let d = Dcg::default();
        let mut raw = RawEnv::new();
        let st = d.build(&mut raw).unwrap();
        for it in 0..10 {
            d.step(&mut raw, &st, it).unwrap();
        }
        d.assisted_rebuild(&mut raw, &st).unwrap();
        for it in 10..d.iters {
            d.step(&mut raw, &st, it).unwrap();
        }
        // A Krylov restart loses conjugacy, so the nominal-end state is
        // not within the S1 acceptance band (that's the paper's S2-heavy
        // CG) — but it must still be a convergent trajectory toward the
        // same solution.
        let m = d.metric(&mut raw, &st).unwrap();
        let g = d.golden();
        let rel = (m - g.metric).abs() / g.metric.abs().max(1.0);
        assert!(rel < 0.1, "post-rebuild run diverged: {} vs {}", m, g.metric);
    }
}

//! botsspar — SPEC OMP 2012 / BOTS "sparselu" blocked sparse LU
//! factorization (sparse linear algebra).
//!
//! An NB×NB grid of B×B blocks with a banded+spokes sparsity pattern
//! (fill-in computed symbolically at init). The main loop is the outer
//! elimination index `k`, with BOTS' four task kernels as code regions:
//!
//! * R0 `lu0`  — factor the diagonal block
//! * R1 `fwd`  — forward-solve row panel
//! * R2 `bdiv` — divide column panel
//! * R3 `bmod` — trailing submatrix update
//!
//! Candidate: the block storage (the in-place factor). Factorization is
//! an exact computation with no convergence loop: restart from stale
//! blocks yields a wrong factor that extra "iterations" cannot repair, so
//! recomputability without persistence is near zero and EasyCrash's
//! per-iteration persistence recovers it — the paper reports one of its
//! largest EasyCrash gains (+77%) on botsspar.

use std::sync::OnceLock;

use super::{AppCore, Golden, RegionSpec};
use crate::sim::{Buf, Env, ObjSpec, Signal};

const NB: usize = 20;
const B: usize = 12;
const BB: usize = B * B;

pub struct Botsspar {
    pub rel_tol: f64,
    gold: OnceLock<Golden>,
}

impl Default for Botsspar {
    fn default() -> Botsspar {
        Botsspar {
            rel_tol: 1e-9,
            gold: OnceLock::new(),
        }
    }
}

pub struct St {
    /// Block storage, NB×NB blocks row-major, each B×B row-major.
    blocks: Buf,
    /// Block presence mask after symbolic fill (read-only).
    mask: Buf,
    it: Buf,
}

impl Botsspar {
    #[inline]
    fn blk(i: usize, j: usize) -> usize {
        (i * NB + j) * BB
    }

    /// Initial sparsity: band + spokes (BOTS-like density ~40-50%).
    fn present_initial(i: usize, j: usize) -> bool {
        i == j
            || i.abs_diff(j) <= 2
            || i % 5 == 0
            || j % 5 == 0
    }

    fn lu0<E: Env>(env: &mut E, blocks: Buf, d: usize) -> Result<(), Signal> {
        let base = Self::blk(d, d);
        for k in 0..B {
            let piv = env.ld(blocks, base + k * B + k)?;
            if piv.abs() < 1e-12 || !piv.is_finite() {
                return Err(Signal::Interrupt); // numerically dead pivot
            }
            for i in k + 1..B {
                let l = env.ld(blocks, base + i * B + k)? / piv;
                env.st(blocks, base + i * B + k, l)?;
                for j in k + 1..B {
                    let a = env.ld(blocks, base + i * B + j)?;
                    let u = env.ld(blocks, base + k * B + j)?;
                    env.st(blocks, base + i * B + j, a - l * u)?;
                }
            }
        }
        Ok(())
    }

    /// Row panel: solve L(diag)·X = A(d,j), in place.
    fn fwd<E: Env>(env: &mut E, blocks: Buf, d: usize, j: usize) -> Result<(), Signal> {
        let diag = Self::blk(d, d);
        let tgt = Self::blk(d, j);
        for k in 0..B {
            for i in k + 1..B {
                let l = env.ld(blocks, diag + i * B + k)?;
                for c in 0..B {
                    let a = env.ld(blocks, tgt + i * B + c)?;
                    let u = env.ld(blocks, tgt + k * B + c)?;
                    env.st(blocks, tgt + i * B + c, a - l * u)?;
                }
            }
        }
        Ok(())
    }

    /// Column panel: solve X·U(diag) = A(i,d), in place.
    fn bdiv<E: Env>(env: &mut E, blocks: Buf, d: usize, i: usize) -> Result<(), Signal> {
        let diag = Self::blk(d, d);
        let tgt = Self::blk(i, d);
        for k in 0..B {
            let piv = env.ld(blocks, diag + k * B + k)?;
            if piv.abs() < 1e-12 || !piv.is_finite() {
                return Err(Signal::Interrupt);
            }
            for r in 0..B {
                let v = env.ld(blocks, tgt + r * B + k)? / piv;
                env.st(blocks, tgt + r * B + k, v)?;
                for c in k + 1..B {
                    let a = env.ld(blocks, tgt + r * B + c)?;
                    let u = env.ld(blocks, diag + k * B + c)?;
                    env.st(blocks, tgt + r * B + c, a - v * u)?;
                }
            }
        }
        Ok(())
    }

    /// Trailing update A(i,j) -= L(i,d)·U(d,j).
    fn bmod<E: Env>(
        env: &mut E,
        blocks: Buf,
        i: usize,
        j: usize,
        d: usize,
    ) -> Result<(), Signal> {
        let l = Self::blk(i, d);
        let u = Self::blk(d, j);
        let t = Self::blk(i, j);
        for r in 0..B {
            for k in 0..B {
                let lv = env.ld(blocks, l + r * B + k)?;
                if lv == 0.0 {
                    continue;
                }
                for c in 0..B {
                    let uv = env.ld(blocks, u + k * B + c)?;
                    let a = env.ld(blocks, t + r * B + c)?;
                    env.st(blocks, t + r * B + c, a - lv * uv)?;
                }
            }
        }
        Ok(())
    }
}

impl AppCore for Botsspar {
    type St = St;

    fn name(&self) -> &'static str {
        "botsspar"
    }

    fn description(&self) -> &'static str {
        "BOTS sparselu: blocked sparse LU factorization with fill-in"
    }

    fn region_specs(&self) -> Vec<RegionSpec> {
        vec![
            RegionSpec::b("lu0"),
            RegionSpec::l("fwd"),
            RegionSpec::l("bdiv"),
            RegionSpec::l("bmod"),
        ]
    }

    fn iters(&self) -> u64 {
        NB as u64
    }

    fn build<E: Env>(&self, env: &mut E) -> Result<St, Signal> {
        let blocks = env.alloc(ObjSpec::f64("blocks", NB * NB * BB, true));
        let mask = env.alloc(ObjSpec::i64("mask", NB * NB, false));
        let it = env.alloc(ObjSpec::i64("it", 1, true));

        // Symbolic fill: mask starts from the structural pattern and gains
        // fill blocks (i,j) whenever (i,d) and (d,j) are present for d <
        // min(i,j) — the BOTS allocation-on-demand behavior, precomputed.
        let mut m = vec![false; NB * NB];
        for i in 0..NB {
            for j in 0..NB {
                m[i * NB + j] = Self::present_initial(i, j);
            }
        }
        for d in 0..NB {
            for i in d + 1..NB {
                if m[i * NB + d] {
                    for j in d + 1..NB {
                        if m[d * NB + j] {
                            m[i * NB + j] = true;
                        }
                    }
                }
            }
        }
        for i in 0..NB {
            for j in 0..NB {
                env.sti(mask, i * NB + j, m[i * NB + j] as i64)?;
            }
        }
        // Block values: deterministic, diagonally dominant.
        for i in 0..NB {
            for j in 0..NB {
                let base = Self::blk(i, j);
                for r in 0..B {
                    for c in 0..B {
                        let v = if !m[i * NB + j] {
                            0.0
                        } else {
                            let h = ((i * 31 + j * 17 + r * 7 + c * 3) % 23) as f64;
                            let mut v = 0.05 * (h - 11.0) / 11.0;
                            if i == j && r == c {
                                v += (B * 2) as f64; // dominance
                            }
                            v
                        };
                        env.st(blocks, base + r * B + c, v)?;
                    }
                }
            }
        }
        env.sti(it, 0, 0)?;
        Ok(St { blocks, mask, it })
    }

    fn step<E: Env>(&self, env: &mut E, st: &St, it: u64) -> Result<(), Signal> {
        let d = it as usize;
        if d >= NB {
            return Ok(()); // factorization complete; extra iters are no-ops
        }
        let present = |env: &mut E, i: usize, j: usize| -> Result<bool, Signal> {
            Ok(env.ldi(st.mask, i * NB + j)? != 0)
        };
        env.region(0)?;
        Self::lu0(env, st.blocks, d)?;
        env.region(1)?;
        for j in d + 1..NB {
            if present(env, d, j)? {
                Self::fwd(env, st.blocks, d, j)?;
            }
        }
        env.region(2)?;
        for i in d + 1..NB {
            if present(env, i, d)? {
                Self::bdiv(env, st.blocks, d, i)?;
            }
        }
        env.region(3)?;
        for i in d + 1..NB {
            if present(env, i, d)? {
                for j in d + 1..NB {
                    if present(env, d, j)? {
                        Self::bmod(env, st.blocks, i, j, d)?;
                    }
                }
            }
        }
        Ok(())
    }

    fn metric<E: Env>(&self, env: &mut E, st: &St) -> Result<f64, Signal> {
        // Weighted checksum of the factor (exact computation: restart from
        // a consistent image reproduces it bit-for-bit).
        let mut s = 0.0f64;
        for i in 0..NB {
            for j in 0..NB {
                if env.ldi(st.mask, i * NB + j)? != 0 {
                    let base = Self::blk(i, j);
                    for e in (0..BB).step_by(7) {
                        let v = env.ld(st.blocks, base + e)?;
                        if !v.is_finite() {
                            return Err(Signal::Interrupt);
                        }
                        s += v * (1.0 + ((i + 2 * j + e) % 13) as f64 * 0.01);
                    }
                }
            }
        }
        Ok(s)
    }

    fn accept(&self, metric: f64, golden: &Golden) -> bool {
        metric.is_finite()
            && (metric - golden.metric).abs() <= self.rel_tol * golden.metric.abs().max(1.0)
    }

    fn iter_buf(st: &St) -> Buf {
        st.it
    }

    fn golden_cell(&self) -> &OnceLock<Golden> {
        &self.gold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{CrashApp, Response, Snapshot};
    use crate::sim::RawEnv;

    #[test]
    fn factorization_reconstructs_matrix() {
        // Multiply L·U back for a sampled block column and compare to the
        // original matrix: the factorization must be correct.
        let app = Botsspar::default();
        let mut orig = RawEnv::new();
        let sto = app.build(&mut orig).unwrap();
        let mut fact = RawEnv::new();
        let stf = app.build(&mut fact).unwrap();
        for it in 0..app.iters() {
            app.step(&mut fact, &stf, it).unwrap();
        }
        // Reconstruct scalar A[r, c] for global rows/cols inside block
        // (i0,j0): A = sum_k L[i0,k-blocks] * U[k,j0-blocks] with unit-lower L.
        let nglob = NB * B;
        let get = |env: &mut RawEnv, st: &St, gi: usize, gj: usize| -> f64 {
            let (bi, bj) = (gi / B, gj / B);
            let (r, c) = (gi % B, gj % B);
            env.ld(st.blocks, Botsspar::blk(bi, bj) + r * B + c).unwrap()
        };
        let lval = |env: &mut RawEnv, st: &St, gi: usize, gk: usize| -> f64 {
            if gk > gi {
                0.0
            } else if gk == gi {
                1.0
            } else {
                get(env, st, gi, gk)
            }
        };
        let uval = |env: &mut RawEnv, st: &St, gk: usize, gj: usize| -> f64 {
            if gk > gj {
                0.0
            } else {
                get(env, st, gk, gj)
            }
        };
        for &(gi, gj) in &[(5usize, 5usize), (17, 3), (40, 55), (100, 100), (150, 7)] {
            let mut s = 0.0;
            for gk in 0..nglob {
                s += lval(&mut fact, &stf, gi, gk) * uval(&mut fact, &stf, gk, gj);
            }
            let a = get(&mut orig, &sto, gi, gj);
            assert!(
                (s - a).abs() < 1e-6 * a.abs().max(1.0),
                "A[{gi},{gj}]: LU={s} vs A={a}"
            );
        }
    }

    #[test]
    fn stale_factor_fails_verification() {
        let app = Botsspar::default();
        let g = app.golden();
        // Bookmark says k=12 but blocks are the *initial* matrix.
        let snap = Snapshot { iter: 12, objs: vec![] };
        let mut eng = crate::runtime::NativeEngine::new();
        let (resp, _) = app.recompute(&snap, &g, &mut eng);
        assert!(resp == Response::S4 || resp == Response::S3);
    }

    #[test]
    fn full_restart_is_s1() {
        let app = Botsspar::default();
        let g = app.golden();
        let snap = Snapshot { iter: 0, objs: vec![] };
        let mut eng = crate::runtime::NativeEngine::new();
        assert_eq!(app.recompute(&snap, &g, &mut eng).0, Response::S1);
    }
}

//! The benchmark suite (paper Table 1): NPB CG/MG/FT/IS/BT/SP/LU/EP plus
//! botsspar (SPEC OMP), LULESH and Rodinia kmeans, re-implemented as
//! mini-class kernels over the [`Env`](crate::sim::Env) abstraction.
//!
//! Each app implements [`AppCore`] once, generically over `Env`; the
//! blanket impl of [`CrashApp`] derives from it:
//!
//! * the instrumented full run ([`CrashApp::run_sim`], the NVCT path),
//! * the memoized golden run (uninstrumented reference execution),
//! * restart + S1–S4 classification from a crash snapshot
//!   ([`CrashApp::recompute`], the campaign hot path, optionally through
//!   the PJRT engine).

use std::sync::OnceLock;

use crate::runtime::StepEngine;
use crate::sim::{Buf, Env, LayoutEnv, LayoutProbe, ObjId, RawEnv, Signal, SimEnv};

pub mod adi;
pub mod bt;
pub mod botsspar;
pub mod cg;
pub mod dcg;
pub mod ep;
pub mod fft;
pub mod ft;
pub mod is;
pub mod kmeans;
pub mod lu;
pub mod lulesh;
pub mod mg;
pub mod sp;
pub mod toy;

/// Static description of one code region (§5.2): a first-level inner loop
/// or the block between two adjacent first-level inner loops.
#[derive(Clone, Debug)]
pub struct RegionSpec {
    pub name: &'static str,
    /// Loop-structured regions support frequency-`x` persistence (Eq. 5);
    /// non-loop regions are flushed at region end or not at all.
    pub is_loop: bool,
}

impl RegionSpec {
    pub fn l(name: &'static str) -> RegionSpec {
        RegionSpec { name, is_loop: true }
    }
    pub fn b(name: &'static str) -> RegionSpec {
        RegionSpec { name, is_loop: false }
    }
}

/// Result of the reference (golden) run.
#[derive(Clone, Copy, Debug)]
pub struct Golden {
    /// Main-loop iteration count of the original execution (Table 1).
    pub iters: u64,
    /// Final value of the app's acceptance-verification metric.
    pub metric: f64,
}

/// Crash snapshot handed from the campaign to `recompute`: the persisted
/// NVM bytes of every candidate object plus the persisted loop-iterator
/// bookmark.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub iter: u64,
    pub objs: Vec<(ObjId, Vec<u8>)>,
}

/// The four application responses after crash + restart (§4.2 / Fig. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Response {
    /// Successful recomputation, no extra iterations.
    S1,
    /// Successful recomputation with ≥1 extra iteration.
    S2,
    /// Interruption (restart could not run to completion, e.g. segfault).
    S3,
    /// Acceptance verification fails even after 2× the original iterations.
    S4,
}

impl Response {
    /// "Recomputes" in the paper's strict sense (§2.2): correct outcome
    /// *and* no extra iterations.
    pub fn recomputes(self) -> bool {
        self == Response::S1
    }

    pub fn label(self) -> &'static str {
        match self {
            Response::S1 => "S1",
            Response::S2 => "S2",
            Response::S3 => "S3",
            Response::S4 => "S4",
        }
    }
}

/// What each benchmark implements, written once and generic over [`Env`].
pub trait AppCore {
    /// Per-app state: the buffers allocated in `build` plus scalars.
    type St;

    fn name(&self) -> &'static str;
    fn description(&self) -> &'static str;
    fn region_specs(&self) -> Vec<RegionSpec>;
    /// Main-loop iteration count of the nominal run.
    fn iters(&self) -> u64;

    /// Allocate every data object and run the initialization phase.
    fn build<E: Env>(&self, env: &mut E) -> Result<Self::St, Signal>;

    /// One main-loop iteration (calls `env.region(k)` at phase boundaries).
    fn step<E: Env>(&self, env: &mut E, st: &Self::St, it: u64) -> Result<(), Signal>;

    /// One main-loop iteration on the fast (recompute) path. Defaults to
    /// the native kernel; flagship apps route through the PJRT engine.
    fn step_fast(
        &self,
        env: &mut RawEnv,
        st: &Self::St,
        it: u64,
        _engine: &mut dyn StepEngine,
    ) -> Result<(), Signal> {
        self.step(env, st, it)
    }

    /// Compute the acceptance-verification metric over current state.
    fn metric<E: Env>(&self, env: &mut E, st: &Self::St) -> Result<f64, Signal>;

    /// Acceptance verification (§2.2): is `metric` an acceptable outcome
    /// given the golden run?
    fn accept(&self, metric: f64, golden: &Golden) -> bool;

    /// The loop-iterator bookmark buffer within `St`.
    fn iter_buf(st: &Self::St) -> Buf;

    /// Memoization cell for the golden run.
    fn golden_cell(&self) -> &OnceLock<Golden>;
}

/// Object-safe interface the coordinator (campaigns, reports, CLI) uses.
///
/// `Send + Sync` so one app instance can be shared by reference across the
/// sharded campaign's worker threads: app structs are plain configuration
/// data plus an `OnceLock`-memoized golden run (every worker that races
/// the initialization computes the identical deterministic value).
pub trait CrashApp: Send + Sync {
    fn name(&self) -> &'static str;
    fn description(&self) -> &'static str;
    fn regions(&self) -> Vec<RegionSpec>;
    fn nominal_iters(&self) -> u64;

    /// Full instrumented run over the NVCT simulator. `Err` only in
    /// halt-at-crash mode.
    fn run_sim(&self, env: &mut SimEnv) -> Result<(), Signal>;

    /// Resume an instrumented run on an env restored from an
    /// [`EnvSnapshot`](crate::sim::EnvSnapshot): runs main-loop iterations
    /// `start_it..iters()` with the exact loop body of [`CrashApp::run_sim`]
    /// (step, bookmark store, `iter_end`). The app's opaque handle state is
    /// rebuilt on a throwaway [`LayoutEnv`] whose allocation layout matches
    /// `SimEnv`'s, so the handles are valid for the restored env while the
    /// rebuild touches neither its images nor its counters. `start_it` must
    /// be the snapshot's [`iter()`](crate::sim::EnvSnapshot::iter) — i.e.
    /// an iteration boundary, the only resumable points.
    fn run_sim_from(&self, env: &mut SimEnv, start_it: u64) -> Result<(), Signal>;

    /// Learn the app's object layout and bookmark identity without an
    /// instrumented run: build on a throwaway [`LayoutEnv`] and return the
    /// registry plus the `ObjId` of the loop-iterator bookmark. Config-
    /// independent (no caches involved), so one probe serves every
    /// (plan, worker) of a campaign.
    fn probe_layout(&self) -> Result<LayoutProbe, Signal>;

    /// Reference run (memoized).
    fn golden(&self) -> Golden;

    /// Restart from a crash snapshot, classify the response, and report
    /// extra iterations used (0 unless S2).
    fn recompute(
        &self,
        snap: &Snapshot,
        golden: &Golden,
        engine: &mut dyn StepEngine,
    ) -> (Response, u64);
}

impl<T: AppCore + Send + Sync> CrashApp for T {
    fn name(&self) -> &'static str {
        AppCore::name(self)
    }

    fn description(&self) -> &'static str {
        AppCore::description(self)
    }

    fn regions(&self) -> Vec<RegionSpec> {
        self.region_specs()
    }

    fn nominal_iters(&self) -> u64 {
        self.iters()
    }

    fn run_sim(&self, env: &mut SimEnv) -> Result<(), Signal> {
        let st = self.build(env)?;
        env.mark_main_start();
        let it_buf = Self::iter_buf(&st);
        for it in 0..self.iters() {
            self.step(env, &st, it)?;
            // Bookmark "resume at it+1"; persisted by iter_end.
            env.sti(it_buf, 0, (it + 1) as i64)?;
            env.iter_end(it)?;
        }
        Ok(())
    }

    fn run_sim_from(&self, env: &mut SimEnv, start_it: u64) -> Result<(), Signal> {
        let mut lay = LayoutEnv::new();
        let st = self.build(&mut lay)?;
        debug_assert_eq!(
            lay.reg.footprint(),
            env.reg.footprint(),
            "restored env must carry the layout run_sim would build"
        );
        let it_buf = Self::iter_buf(&st);
        for it in start_it..self.iters() {
            self.step(env, &st, it)?;
            env.sti(it_buf, 0, (it + 1) as i64)?;
            env.iter_end(it)?;
        }
        Ok(())
    }

    fn probe_layout(&self) -> Result<LayoutProbe, Signal> {
        let mut lay = LayoutEnv::new();
        let st = self.build(&mut lay)?;
        let iter_obj = Some(Self::iter_buf(&st).id);
        Ok(LayoutProbe { reg: lay.reg, iter_obj })
    }

    fn golden(&self) -> Golden {
        *self.golden_cell().get_or_init(|| {
            let mut raw = RawEnv::new();
            let st = self.build(&mut raw).expect("golden build cannot fail");
            for it in 0..self.iters() {
                self.step(&mut raw, &st, it).expect("golden step cannot fail");
            }
            let metric = self
                .metric(&mut raw, &st)
                .expect("golden metric cannot fail");
            Golden {
                iters: self.iters(),
                metric,
            }
        })
    }

    fn recompute(
        &self,
        snap: &Snapshot,
        golden: &Golden,
        engine: &mut dyn StepEngine,
    ) -> (Response, u64) {
        let mut raw = RawEnv::new();
        // Restart = re-initialize, then overlay persisted candidates
        // (Fig. 2b: initialize(); load_value(...); resume main loop).
        let st = match self.build(&mut raw) {
            Ok(s) => s,
            Err(_) => return (Response::S3, 0),
        };
        for (id, bytes) in &snap.objs {
            match raw.buf_of(*id) {
                Some(buf) if buf.len as usize * buf.ty.bytes() == bytes.len() => {
                    raw.load_bytes(buf, bytes)
                }
                _ => return (Response::S3, 0),
            }
        }
        let nominal = self.iters();
        let start = snap.iter.min(nominal);
        // Run the remaining nominal iterations.
        for it in start..nominal {
            if let Err(_s) = self.step_fast(&mut raw, &st, it, engine) {
                return (Response::S3, 0);
            }
        }
        match self.metric(&mut raw, &st) {
            Ok(m) if self.accept(m, golden) => return (Response::S1, 0),
            Ok(_) => {}
            Err(_) => return (Response::S3, 0),
        }
        // Verification failed at the nominal end: allow extra iterations up
        // to 2× the original execution (§4.2 response definitions).
        let max = nominal * 2;
        for it in nominal..max {
            if let Err(_s) = self.step_fast(&mut raw, &st, it, engine) {
                return (Response::S3, it - nominal);
            }
            match self.metric(&mut raw, &st) {
                Ok(m) if self.accept(m, golden) => return (Response::S2, it - nominal + 1),
                Ok(_) => {}
                Err(_) => return (Response::S3, it - nominal),
            }
        }
        (Response::S4, max - nominal)
    }
}

/// All paper benchmarks, default mini-class configurations, in Table 1
/// order.
pub fn all() -> Vec<Box<dyn CrashApp>> {
    vec![
        Box::new(cg::Cg::default()),
        Box::new(mg::Mg::default()),
        Box::new(ft::Ft::default()),
        Box::new(is::Is::default()),
        Box::new(bt::Bt::default()),
        Box::new(lu::Lu::default()),
        Box::new(sp::Sp::default()),
        Box::new(ep::Ep::default()),
        Box::new(botsspar::Botsspar::default()),
        Box::new(lulesh::Lulesh::default()),
        Box::new(kmeans::Kmeans::default()),
    ]
}

/// The Fig. 5/6/Table-4 evaluation set: every benchmark except EP, whose
/// inherent recomputability is ~0 and which the paper excludes from the
/// EasyCrash evaluation (§6).
pub fn eval_set() -> Vec<Box<dyn CrashApp>> {
    all().into_iter().filter(|a| a.name() != "ep").collect()
}

/// Non-paper extras: the `toy` test kernel, the `adi` and `fft`
/// substrate mini apps, and the multi-rank `dcg` solver. Resolvable by
/// name and part of the full determinism matrix
/// (`rust/tests/determinism.rs` covers `all() + extras()` — 15 apps),
/// but excluded from the Table-1 registry the figures sweep.
pub fn extras() -> Vec<Box<dyn CrashApp>> {
    vec![
        Box::new(toy::Toy::default()),
        Box::new(adi::Adi::default()),
        Box::new(fft::Fft::default()),
        Box::new(dcg::Dcg::default()),
    ]
}

/// Look up a benchmark by name (incl. the non-paper extras).
pub fn by_name(name: &str) -> Option<Box<dyn CrashApp>> {
    all()
        .into_iter()
        .chain(extras())
        .find(|a| a.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_eleven_paper_apps() {
        let apps = all();
        assert_eq!(apps.len(), 11);
        let names: Vec<_> = apps.iter().map(|a| a.name()).collect();
        for expected in [
            "cg", "mg", "ft", "is", "bt", "lu", "sp", "ep", "botsspar", "lulesh", "kmeans",
        ] {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }

    #[test]
    fn eval_set_excludes_ep() {
        assert!(eval_set().iter().all(|a| a.name() != "ep"));
        assert_eq!(eval_set().len(), 10);
    }

    #[test]
    fn by_name_resolves() {
        assert!(by_name("mg").is_some());
        assert!(by_name("toy").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn extras_complete_the_fifteen_app_matrix() {
        let ex = extras();
        let names: Vec<_> = ex.iter().map(|a| a.name()).collect();
        assert_eq!(names, vec!["toy", "adi", "fft", "dcg"]);
        assert!(by_name("adi").is_some());
        assert!(by_name("fft").is_some());
        assert!(by_name("dcg").is_some());
        // No name collides with the paper registry, and the full matrix
        // is 15 apps.
        let all_names: Vec<_> = all().iter().map(|a| a.name()).collect();
        assert!(names.iter().all(|n| !all_names.contains(n)));
        assert_eq!(all().len() + ex.len(), 15);
    }
}

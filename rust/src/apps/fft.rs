//! Radix-2 complex FFT substrate for the FT benchmark — plus [`Fft`],
//! the substrate exposed as a standalone spectral-filter mini app.
//!
//! Iterative (bit-reversal + butterfly) Cooley–Tukey over the [`Env`]
//! abstraction, operating on split re/im f64 buffers with an arbitrary
//! stride so the same routine serves all three dimensions of FT's 3-D
//! transform. Twiddle factors are computed on the fly (sin/cos are CPU
//! work, not memory traffic, so this keeps the simulated access stream
//! faithful to an in-place FFT).

use std::sync::OnceLock;

use super::{AppCore, Golden, RegionSpec};
use crate::sim::{Buf, Env, ObjSpec, Signal};

/// In-place FFT of length `n` (power of two) over elements
/// `base + k*stride` of the split complex arrays `(re, im)`.
/// `inverse` selects the conjugate transform (unnormalized — FT divides
/// once by the total size like NPB does).
pub fn fft_strided<E: Env>(
    env: &mut E,
    re: Buf,
    im: Buf,
    base: usize,
    stride: usize,
    n: usize,
    inverse: bool,
) -> Result<(), Signal> {
    debug_assert!(n.is_power_of_two());
    let at = |k: usize| base + k * stride;

    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for k in 0..n {
        let j = (k.reverse_bits() >> (usize::BITS - bits)) as usize;
        if j > k {
            let (ar, ai) = (env.ld(re, at(k))?, env.ld(im, at(k))?);
            let (br, bi) = (env.ld(re, at(j))?, env.ld(im, at(j))?);
            env.st(re, at(k), br)?;
            env.st(im, at(k), bi)?;
            env.st(re, at(j), ar)?;
            env.st(im, at(j), ai)?;
        }
    }

    // Butterflies.
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr0, wi0) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut wr, mut wi) = (1.0f64, 0.0f64);
            for j in 0..len / 2 {
                let a = at(i + j);
                let b = at(i + j + len / 2);
                let (ar, ai) = (env.ld(re, a)?, env.ld(im, a)?);
                let (br, bi) = (env.ld(re, b)?, env.ld(im, b)?);
                let (tr, ti) = (br * wr - bi * wi, br * wi + bi * wr);
                env.st(re, a, ar + tr)?;
                env.st(im, a, ai + ti)?;
                env.st(re, b, ar - tr)?;
                env.st(im, b, ai - ti)?;
                let nwr = wr * wr0 - wi * wi0;
                wi = wr * wi0 + wi * wr0;
                wr = nwr;
            }
            i += len;
        }
        len <<= 1;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The substrate as a standalone mini app
// ---------------------------------------------------------------------------

/// `fft` — a 1-D spectral low-pass filter built on [`fft_strided`]. Each
/// iteration transforms the signal, damps the upper half of the
/// spectrum, transforms back and renormalizes. Not part of the paper's
/// Table 1 set (FT is the production 3-D transform); it completes the
/// 14-app determinism matrix with an FFT-shaped access pattern whose
/// mid-transform crash states are *not* recomputable from the data alone
/// (a half-butterflied array is garbage to a restart), giving the matrix
/// a low-recomputability spectral workload.
pub struct Fft {
    pub n: usize,
    pub iters: u64,
    gold: OnceLock<Golden>,
}

impl Default for Fft {
    fn default() -> Fft {
        Fft {
            n: 1 << 11,
            iters: 10,
            gold: OnceLock::new(),
        }
    }
}

pub struct FftSt {
    re: Buf,
    im: Buf,
    it: Buf,
}

impl AppCore for Fft {
    type St = FftSt;

    fn name(&self) -> &'static str {
        "fft"
    }

    fn description(&self) -> &'static str {
        "mini FFT: iterative 1-D spectral low-pass filter"
    }

    fn region_specs(&self) -> Vec<RegionSpec> {
        vec![
            RegionSpec::l("forward"),
            RegionSpec::l("filter"),
            RegionSpec::l("inverse"),
        ]
    }

    fn iters(&self) -> u64 {
        self.iters
    }

    fn build<E: Env>(&self, env: &mut E) -> Result<FftSt, Signal> {
        let re = env.alloc(ObjSpec::f64("re", self.n, true));
        let im = env.alloc(ObjSpec::f64("im", self.n, true));
        let it = env.alloc(ObjSpec::i64("it", 1, true));
        for k in 0..self.n {
            let x = k as f64;
            env.st(re, k, (0.37 * x).sin() + 0.3 * (2.3 * x).cos())?;
            env.st(im, k, 0.0)?;
        }
        env.sti(it, 0, 0)?;
        Ok(FftSt { re, im, it })
    }

    fn step<E: Env>(&self, env: &mut E, st: &FftSt, _it: u64) -> Result<(), Signal> {
        let n = self.n;
        // R0: forward transform.
        env.region(0)?;
        fft_strided(env, st.re, st.im, 0, 1, n, false)?;
        // R1: damp the upper half of the spectrum (modes n/4 .. 3n/4).
        env.region(1)?;
        for k in n / 4..3 * n / 4 {
            let r = env.ld(st.re, k)? * 0.5;
            env.st(st.re, k, r)?;
            let i = env.ld(st.im, k)? * 0.5;
            env.st(st.im, k, i)?;
        }
        // R2: inverse transform + 1/n normalization (fft_strided is
        // unnormalized, like NPB).
        env.region(2)?;
        fft_strided(env, st.re, st.im, 0, 1, n, true)?;
        let inv = 1.0 / n as f64;
        for k in 0..n {
            let r = env.ld(st.re, k)? * inv;
            env.st(st.re, k, r)?;
            let i = env.ld(st.im, k)? * inv;
            env.st(st.im, k, i)?;
        }
        Ok(())
    }

    fn metric<E: Env>(&self, env: &mut E, st: &FftSt) -> Result<f64, Signal> {
        // Signal energy: strictly decaying under the filter, and wildly
        // wrong (≈ n× too large, or mid-butterfly garbage) when a crash
        // image is replayed from an inconsistent transform state.
        let mut s = 0.0;
        for k in 0..self.n {
            let r = env.ld(st.re, k)?;
            let i = env.ld(st.im, k)?;
            s += r * r + i * i;
        }
        Ok(s)
    }

    fn accept(&self, metric: f64, golden: &Golden) -> bool {
        metric.is_finite() && (metric - golden.metric).abs() <= 0.05 * golden.metric.abs()
    }

    fn iter_buf(st: &FftSt) -> Buf {
        st.it
    }

    fn golden_cell(&self) -> &OnceLock<Golden> {
        &self.gold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RawEnv;

    fn alloc_pair(env: &mut RawEnv, n: usize) -> (Buf, Buf) {
        (
            env.alloc(ObjSpec::f64("re", n, true)),
            env.alloc(ObjSpec::f64("im", n, true)),
        )
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut env = RawEnv::new();
        let (re, im) = alloc_pair(&mut env, 16);
        env.st(re, 0, 1.0).unwrap();
        fft_strided(&mut env, re, im, 0, 1, 16, false).unwrap();
        for k in 0..16 {
            assert!((env.ld(re, k).unwrap() - 1.0).abs() < 1e-12);
            assert!(env.ld(im, k).unwrap().abs() < 1e-12);
        }
    }

    #[test]
    fn roundtrip_recovers_signal() {
        let mut env = RawEnv::new();
        let n = 64;
        let (re, im) = alloc_pair(&mut env, n);
        for k in 0..n {
            env.st(re, k, (k as f64 * 0.37).sin()).unwrap();
            env.st(im, k, (k as f64 * 0.11).cos()).unwrap();
        }
        let orig: Vec<(f64, f64)> = (0..n)
            .map(|k| (env.ld(re, k).unwrap(), env.ld(im, k).unwrap()))
            .collect();
        fft_strided(&mut env, re, im, 0, 1, n, false).unwrap();
        fft_strided(&mut env, re, im, 0, 1, n, true).unwrap();
        for k in 0..n {
            assert!((env.ld(re, k).unwrap() / n as f64 - orig[k].0).abs() < 1e-10);
            assert!((env.ld(im, k).unwrap() / n as f64 - orig[k].1).abs() < 1e-10);
        }
    }

    #[test]
    fn strided_equals_contiguous() {
        // FFT along a strided slice must equal the contiguous result.
        let n = 32;
        let mut a = RawEnv::new();
        let (re_a, im_a) = alloc_pair(&mut a, n);
        let mut b = RawEnv::new();
        let (re_b, im_b) = alloc_pair(&mut b, n * 4);
        for k in 0..n {
            let v = (k as f64 * 0.77).sin();
            a.st(re_a, k, v).unwrap();
            b.st(re_b, k * 4, v).unwrap();
        }
        fft_strided(&mut a, re_a, im_a, 0, 1, n, false).unwrap();
        fft_strided(&mut b, re_b, im_b, 0, 4, n, false).unwrap();
        for k in 0..n {
            assert!(
                (a.ld(re_a, k).unwrap() - b.ld(re_b, k * 4).unwrap()).abs() < 1e-10
            );
            assert!(
                (a.ld(im_a, k).unwrap() - b.ld(im_b, k * 4).unwrap()).abs() < 1e-10
            );
        }
    }

    #[test]
    fn standalone_fft_app_filters_energy_downward() {
        use crate::apps::CrashApp;
        let app = Fft { n: 256, iters: 6, gold: OnceLock::new() };
        assert_eq!(app.regions().len(), 3);
        let mut raw = RawEnv::new();
        let st = app.build(&mut raw).unwrap();
        let e0 = app.metric(&mut raw, &st).unwrap();
        let mut prev = e0;
        for it in 0..app.iters {
            app.step(&mut raw, &st, it).unwrap();
            let e = app.metric(&mut raw, &st).unwrap();
            assert!(e.is_finite() && e <= prev + 1e-9 * e0, "filter must not add energy");
            prev = e;
        }
        assert!(prev < e0, "damping must remove energy: {e0} -> {prev}");
        // The golden run replays the identical arithmetic.
        let g = app.golden();
        assert_eq!(g.iters, 6);
        assert!((g.metric - prev).abs() <= 1e-12 * prev.abs().max(1.0));
        assert!(app.accept(g.metric, &g));
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut env = RawEnv::new();
        let n = 128;
        let (re, im) = alloc_pair(&mut env, n);
        for k in 0..n {
            env.st(re, k, (k as f64).cos()).unwrap();
        }
        let e_time: f64 = (0..n)
            .map(|k| {
                let r = env.ld(re, k).unwrap();
                r * r
            })
            .sum();
        fft_strided(&mut env, re, im, 0, 1, n, false).unwrap();
        let e_freq: f64 = (0..n)
            .map(|k| {
                let r = env.ld(re, k).unwrap();
                let i = env.ld(im, k).unwrap();
                r * r + i * i
            })
            .sum::<f64>()
            / n as f64;
        assert!((e_time - e_freq).abs() < 1e-8 * e_time);
    }
}

//! Report generators — one per table/figure in the paper's evaluation —
//! plus the CLI dispatch. Each generator prints the paper's rows to
//! stdout and writes a CSV under `results/`.

pub mod context;
pub mod fig10;
pub mod fig11;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod table1;
pub mod table4;

use crate::sim::NvmProfile;
use crate::util::cli::Args;
use crate::util::table::Table;

pub use context::ReportCtx;

fn emit(name: &str, title: &str, t: &Table) -> crate::util::error::Result<()> {
    println!("\n== {title} ==");
    print!("{}", t.render());
    let path = t.save_csv(name)?;
    println!("[csv] {}", path.display());
    Ok(())
}

/// The per-app workflow summary (selection details; used by the
/// `workflow` subcommand).
fn cmd_workflow(ctx: &ReportCtx, args: &Args) -> crate::util::error::Result<()> {
    let name = args.get_or("app", "mg");
    let app = crate::apps::by_name(name)
        .ok_or_else(|| crate::err!("unknown app `{name}`"))?;
    let wf = ctx.workflow(app.as_ref())?;
    println!("== EasyCrash workflow for {name} (planner: {}) ==", wf.planner);
    println!("step 1: characterization campaign ({} tests)", wf.base.records.len());
    println!(
        "  recomputability without persistence: {}",
        crate::util::pct(wf.base.recomputability())
    );
    println!("step 2: data-object selection ({}):", wf.planner.selector);
    let mut t = Table::new(&["object", "bytes", "Rs", "p", "critical"]);
    for r in &wf.selection {
        t.row(vec![
            r.name.clone(),
            crate::util::human_bytes(r.bytes as u64),
            format!("{:+.3}", r.rs),
            format!("{:.2e}", r.p),
            if r.selected { "yes".into() } else { "no".into() },
        ]);
    }
    print!("{}", t.render());
    println!("step 3: code-region selection (t_s={}, tau={}):", ctx.ts, ctx.tau);
    let regions = app.regions();
    let mut t = Table::new(&["region", "a_k", "c_k", "c_k^max", "l_k", "chosen x"]);
    for k in 0..regions.len() {
        let chosen = wf
            .region_sel
            .choices
            .iter()
            .find(|c| c.region == k)
            .map(|c| c.x.to_string())
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            format!("R{k} ({})", regions[k].name),
            format!("{:.3}", wf.model.a[k]),
            format!("{:.2}", wf.model.c[k]),
            format!("{:.2}", wf.model.cmax[k]),
            format!("{:.4}", wf.model.l[k]),
            chosen,
        ]);
    }
    print!("{}", t.render());
    println!(
        "  predicted Y'={} overhead={:.2}% meets tau: {}",
        crate::util::pct(wf.region_sel.predicted_y),
        wf.region_sel.predicted_overhead * 100.0,
        wf.region_sel.meets_tau
    );
    println!("step 4: production plan: {:?}", wf.plan.entries);
    println!(
        "  final recomputability: {} (best config: {})",
        crate::util::pct(wf.final_result.recomputability()),
        crate::util::pct(wf.best.recomputability())
    );
    Ok(())
}

/// §6 sensitivity study: t_s ∈ {2%, 3%, 5%}.
fn cmd_sensitivity(base_args: &Args) -> crate::util::error::Result<()> {
    for ts in [0.02, 0.03, 0.05] {
        let mut args = base_args.clone();
        args.options.insert("ts".into(), ts.to_string());
        let ctx = ReportCtx::from_args(&args)?;
        let mut t = Table::new(&["app", "Y' predicted", "overhead", "meets tau"]);
        for app in ctx.eval_apps() {
            let wf = ctx.workflow(app.as_ref())?;
            t.row(vec![
                app.name().into(),
                crate::util::pct(wf.region_sel.predicted_y),
                format!("{:.2}%", wf.region_sel.predicted_overhead * 100.0),
                wf.region_sel.meets_tau.to_string(),
            ]);
        }
        emit(
            &format!("sensitivity_ts{}", (ts * 100.0) as u32),
            &format!("Sensitivity: t_s = {:.0}%", ts * 100.0),
            &t,
        )?;
    }
    Ok(())
}

/// Dispatch a report subcommand. `cmd` is the first positional argument.
pub fn cli_dispatch(cmd: &str, args: &Args) -> crate::util::error::Result<()> {
    match cmd {
        "help" | "--help" | "-h" => {
            print_help();
            return Ok(());
        }
        "sensitivity" => return cmd_sensitivity(args),
        _ => {}
    }
    let ctx = ReportCtx::from_args(args)?;
    match cmd {
        "workflow" => cmd_workflow(&ctx, args)?,
        "table1" => emit("table1", "Table 1: benchmark information", &table1::run(&ctx)?)?,
        "fig3" => emit("fig3", "Figure 3: responses after crash+restart", &fig3::run(&ctx)?)?,
        "fig4" => {
            let (a, b) = fig4::run(&ctx)?;
            emit("fig4a", "Figure 4a: MG, persisting individual objects", &a)?;
            emit("fig4b", "Figure 4b: MG, persisting u per region", &b)?;
        }
        "fig5" => emit("fig5", "Figure 5: three persistence strategies", &fig5::run(&ctx)?)?,
        "fig6" => emit("fig6", "Figure 6: recomputability by method", &fig6::run(&ctx)?)?,
        "table4" => emit("table4", "Table 4: normalized execution time", &table4::run(&ctx)?)?,
        "fig7" => emit(
            "fig7",
            "Figure 7: normalized time under NVM profiles",
            &fig7::run(&ctx, &NvmProfile::ALL_FIG7)?,
        )?,
        "fig8" => emit(
            "fig8",
            "Figure 8: normalized time on Optane DC PMM",
            &fig7::run(&ctx, &[NvmProfile::OPTANE])?,
        )?,
        "fig9" => emit("fig9", "Figure 9: normalized NVM writes", &fig9::run(&ctx)?)?,
        "fig10" => emit("fig10", "Figure 10: system efficiency vs T_chk", &fig10::run(&ctx)?)?,
        "fig11" => emit("fig11", "Figure 11: system efficiency vs scale", &fig11::run(&ctx)?)?,
        "all" => {
            emit("table1", "Table 1: benchmark information", &table1::run(&ctx)?)?;
            emit("fig3", "Figure 3: responses after crash+restart", &fig3::run(&ctx)?)?;
            let (a, b) = fig4::run(&ctx)?;
            emit("fig4a", "Figure 4a: MG, persisting individual objects", &a)?;
            emit("fig4b", "Figure 4b: MG, persisting u per region", &b)?;
            emit("fig5", "Figure 5: three persistence strategies", &fig5::run(&ctx)?)?;
            emit("fig6", "Figure 6: recomputability by method", &fig6::run(&ctx)?)?;
            emit("table4", "Table 4: normalized execution time", &table4::run(&ctx)?)?;
            emit(
                "fig7",
                "Figure 7: normalized time under NVM profiles",
                &fig7::run(&ctx, &NvmProfile::ALL_FIG7)?,
            )?;
            emit(
                "fig8",
                "Figure 8: normalized time on Optane DC PMM",
                &fig7::run(&ctx, &[NvmProfile::OPTANE])?,
            )?;
            emit("fig9", "Figure 9: normalized NVM writes", &fig9::run(&ctx)?)?;
            emit("fig10", "Figure 10: system efficiency vs T_chk", &fig10::run(&ctx)?)?;
            emit("fig11", "Figure 11: system efficiency vs scale", &fig11::run(&ctx)?)?;
        }
        other => {
            print_help();
            crate::bail!("unknown command `{other}`");
        }
    }
    Ok(())
}

fn print_help() {
    println!(
        "easycrash — reproduction of 'EasyCrash: Exploring Non-Volatility of NVM for HPC Under Failures'

USAGE: easycrash <command> [--tests N] [--seed S] [--engine native|pjrt|pool]
                 [--shards N] [--ts F] [--tau F] [--planner SEL[+PLACER]]
                 [--sampler uniform|classes|adaptive[(R)]]
                 [--snapshot-interval N] [--paper-scale] [--verbose]
                 [--store-dir DIR | --no-store]

campaign and profile results are cached in a durable content-addressed
store (default `.easycrash-store/`, or $EASYCRASH_STORE, or --store-dir
DIR): any command that repeats a cell — across runs, restarts and
processes — reads the stored result instead of re-simulating. Corrupt or
version-skewed entries are detected (checksummed entries) and silently
recomputed. --no-store disables the cache for one run.

--engine pool runs every campaign test against a durable mmap-backed pool
file: the app is halted at the sampled op, its architectural state is
discarded, and recovery is a two-phase restart from what the pool file
retained (shards must stay 1; verified mode does not apply).

--shards N runs every crash campaign across N worker threads; results are
bit-identical to --shards 1 under the same seed (native engine only).

--snapshot-interval N records an environment snapshot every N instrumented
ops during the campaign's profile pass; crash harvesting then resumes each
batch from the nearest preceding snapshot instead of replaying from op 0.
Results stay bit-identical to scratch replay (0 or omitted disables).

plans are written in the plan DSL: `none`, `all` (all candidate objects at
iteration end), `critical` (workflow-selected objects at iteration end), or
explicit `obj@region/x` entries separated by commas (persist `obj` at the
end of region `region` every `x` iterations; `/x` defaults to `/1`).

planners are written in the planner DSL `selector[+placer]` and swap the
workflow's decision procedure everywhere (`critical` plans, `workflow`,
figures):
  selectors: spearman[(p=F)]  §5.1 correlation selection (default p=0.01)
             topk(K)          K highest mean-inconsistency candidates
             all              every candidate object
             random(SEED)     seeded coin per candidate (floor baseline)
  placers:   knapsack-vs-iterend  knapsack AND budget-fit iteration end,
                                  best measured wins (default)
             knapsack             §5.2 multi-choice knapsack only
             iterend              budget-fit iteration-end placement
             greedy               greedy gain/cost frequency search

samplers choose which crash points a campaign tests (`--sampler`); every
sampled campaign reports `easycrash.coverage/v1` class coverage:
  uniform      stratified-uniform draw over the main-loop ops (default)
  classes      one representative per crash-equivalence class (crash points
               between consecutive persistent-state mutations recover
               identically), aggregates weighted by class width
  adaptive[(R)] successive halving over R op-range regions (default 8),
               reallocating tests toward regions with mixed S1-S4 outcomes
  (classes/adaptive need persistence-equivalent points: native engine
  only, not --verified)

paper artifacts:
  table1 fig3 fig4 fig5 fig6 table4 fig7 fig8 fig9 fig10 fig11
  all            regenerate everything (CSV under results/)
  sensitivity    t_s ∈ {{2,3,5}}%% study
  (--trace adds a Monte Carlo simulated-efficiency column to fig10/fig11)

tools:
  list                         list benchmarks
  probe    --app A [--tests N] [--shards N] timing probe for one app
  campaign --app A --plan none|all|critical|obj@region/x[,..] [--shards N]
  kill-campaign --app A [--plan none|all|obj@region/x[,..]] [--tests N]
             [--seed S] [--pool FILE] [--timeout-secs N] [--retries N]
             [--backoff-ms N]
             real-process crash campaign: spawn a child per kill point,
             SIGKILL it against the pool file, restart and classify the
             two-phase recovery (watchdog + bounded retry)
  rank-campaign [--ranks N] [--recovery local|assisted|global] [--tests N]
             [--plan none|all|obj@region/x[,..]] [--engine native|pool]
             [--shards N] [--out F]
             multi-rank crash campaign on the dcg solver: kill one of N
             ranks per sampled (rank, op) point and classify recovery —
             local (NVM image alone), assisted (survivors rebuild the
             lost block), global (all ranks roll back); all three modes
             when --recovery is absent. --engine pool uses per-rank
             durable pool files (<base>.rank<k>)
  experiment [--spec FILE.json] [--apps A,B] [--plans P1;P2;..] [--out F]
             [--verified|--no-verified] [--server ADDR]
             run an apps x plans experiment spec end to end and write the
             typed JSON report (flags override spec-file fields; plans are
             `;`-separated DSL entries). With --server ADDR the spec is
             submitted to a running `easycrash serve` instead of executing
             locally; the streamed report is written byte-identically to a
             local run's --out file
  serve      [--addr HOST:PORT|unix:/path.sock] [--workers N]
             [--store-dir DIR | --no-store]
             long-lived job server: POST an experiment spec to /jobs and
             stream per-cell NDJSON progress plus the finished report.
             Identical cells across concurrent jobs simulate once
             (single-flight), one worker pool schedules all jobs' cells,
             and the durable store serves previously computed cells
             instantly (default addr 127.0.0.1:7979)
  efficiency [--spec FILE.json] [--apps A,B] [--plans P1;..] [--out F]
             [--trials N] [--work SECS] [--mtbf SECS] [--dist exp|weibull:K]
             measure recomputability per cell with a crash campaign, then
             validate the §7 model with the Monte Carlo failure-timeline
             simulator at T_chk in {{32,320,3200}}s; writes the
             `easycrash.trace/v1` JSON document
  planner-matrix [--apps A,B] [--planners \"S1+P1;S2+P2;..\"] [--out F]
             sweep selector+placer strategy pairs (default: the 3x3 grid
             spearman|topk(3)|all x knapsack|iterend|greedy), one full
             workflow per (app, pair); writes the round-trippable
             `easycrash.planner/v1` JSON document
  workflow --app A [--planner S[+P]]
             run + display the 4-step EasyCrash workflow"
    );
}

//! Figures 7 & 8: normalized execution time with and without EasyCrash
//! under NVM performance profiles (Quartz-style 4×/8× latency, 1/6 & 1/8
//! bandwidth for Fig. 7; the Optane DC PMM profile for Fig. 8).
//! "Without EasyCrash" persists all candidates at every iteration end,
//! exactly the paper's comparison.

use crate::easycrash::PersistPlan;
use crate::sim::NvmProfile;
use crate::util::{mean, table::Table};

use super::context::ReportCtx;

pub fn run(ctx: &ReportCtx, profiles: &[NvmProfile]) -> crate::util::error::Result<Table> {
    let mut headers: Vec<String> = vec!["app".to_string()];
    for p in profiles {
        headers.push(format!("EC {}", p.name));
        headers.push(format!("noEC {}", p.name));
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);

    let mut per_profile_ec: Vec<Vec<f64>> = vec![Vec::new(); profiles.len()];
    let mut per_profile_all: Vec<Vec<f64>> = vec![Vec::new(); profiles.len()];
    for app in ctx.eval_apps() {
        let wf = ctx.workflow(app.as_ref())?;
        let all_plan = ctx.plan_all_candidates(app.as_ref())?;
        let mut row = vec![app.name().to_string()];
        for (i, p) in profiles.iter().enumerate() {
            let cfg = ctx.cfg.with_nvm(*p);
            let base = ctx.profile(app.as_ref(), &PersistPlan::none(), cfg)?;
            let ec = ctx.profile(app.as_ref(), &wf.plan, cfg)?;
            let all = ctx.profile(app.as_ref(), &all_plan, cfg)?;
            let (ne, na) = (ec.cycles / base.cycles, all.cycles / base.cycles);
            per_profile_ec[i].push(ne);
            per_profile_all[i].push(na);
            row.push(format!("{ne:.3}"));
            row.push(format!("{na:.3}"));
        }
        t.row(row);
    }
    let mut avg_row = vec!["average".to_string()];
    for i in 0..profiles.len() {
        avg_row.push(format!("{:.3}", mean(&per_profile_ec[i])));
        avg_row.push(format!("{:.3}", mean(&per_profile_all[i])));
    }
    t.row(avg_row);
    Ok(t)
}

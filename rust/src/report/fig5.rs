//! Figure 5: recomputability under the three persistence strategies —
//! (1) no persistence, (2) the selected critical data objects, (3) all
//! candidate data objects (both persisted at each main-loop iteration
//! end). The paper's §5.1 validation: (2) ≈ (3).

use crate::easycrash::PersistPlan;
use crate::util::{pct, table::Table};

use super::context::ReportCtx;

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let mut t = Table::new(&["app", "no persist", "selected DOs", "all candidate DOs", "|Δ(2,3)|"]);
    let mut max_gap = 0.0f64;
    for app in ctx.eval_apps() {
        let base = ctx.campaign(app.as_ref(), &PersistPlan::none(), false)?;
        let sel_plan = ctx.plan_critical_iter_end(app.as_ref())?;
        let sel = ctx.campaign(app.as_ref(), &sel_plan, false)?;
        let all_plan = ctx.plan_all_candidates(app.as_ref())?;
        let all = ctx.campaign(app.as_ref(), &all_plan, false)?;
        let gap = (sel.recomputability() - all.recomputability()).abs();
        max_gap = max_gap.max(gap);
        t.row(vec![
            app.name().into(),
            pct(base.recomputability()),
            pct(sel.recomputability()),
            pct(all.recomputability()),
            pct(gap),
        ]);
    }
    println!("max |selected - all| gap: {} (paper: <3%)", pct(max_gap));
    Ok(t)
}

//! Shared context for the report generators: configuration + memoized
//! campaigns/workflows so figures that share measurements (Fig. 5/6,
//! Table 1/4...) run each campaign once.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::apps::{self, CrashApp};
use crate::easycrash::workflow::{Workflow, WorkflowReport};
use crate::easycrash::{Campaign, CampaignResult, PersistPlan};
use crate::runtime::{NativeEngine, StepEngine};
use crate::sim::SimConfig;
use crate::util::cli::Args;

pub struct ReportCtx {
    pub tests: usize,
    pub seed: u64,
    pub ts: f64,
    pub tau: f64,
    pub cfg: SimConfig,
    pub verbose: bool,
    engine: RefCell<Box<dyn StepEngine>>,
    workflows: RefCell<HashMap<String, Rc<WorkflowReport>>>,
    campaigns: RefCell<HashMap<String, Rc<CampaignResult>>>,
}

impl ReportCtx {
    pub fn from_args(args: &Args) -> anyhow::Result<ReportCtx> {
        let tests = args
            .usize_or("tests", if args.flag("paper-scale") { 1000 } else { 200 })
            .map_err(|e| anyhow::anyhow!(e))?;
        let engine: Box<dyn StepEngine> = match args.get_or("engine", "native") {
            "native" => Box::new(NativeEngine::new()),
            "pjrt" => Box::new(crate::runtime::PjrtEngine::from_default_dir()?),
            other => anyhow::bail!("unknown engine `{other}`"),
        };
        Ok(ReportCtx {
            tests,
            seed: args.u64_or("seed", 0xEC).map_err(|e| anyhow::anyhow!(e))?,
            ts: args.f64_or("ts", 0.03).map_err(|e| anyhow::anyhow!(e))?,
            tau: args.f64_or("tau", 0.10).map_err(|e| anyhow::anyhow!(e))?,
            cfg: SimConfig::mini(),
            verbose: args.flag("verbose"),
            engine: RefCell::new(engine),
            workflows: RefCell::new(HashMap::new()),
            campaigns: RefCell::new(HashMap::new()),
        })
    }

    pub fn campaign_runner(&self) -> Campaign {
        Campaign {
            tests: self.tests,
            seed: self.seed,
            cfg: self.cfg,
            verified: false,
        }
    }

    /// Memoized full workflow for one app.
    pub fn workflow(&self, app: &dyn CrashApp) -> Rc<WorkflowReport> {
        if let Some(w) = self.workflows.borrow().get(app.name()) {
            return w.clone();
        }
        if self.verbose {
            eprintln!("[workflow] {}", app.name());
        }
        let wf = Workflow {
            tests: self.tests,
            seed: self.seed,
            ts: self.ts,
            tau: self.tau,
            cfg: self.cfg,
        };
        let rep = Rc::new(wf.run(app, self.engine.borrow_mut().as_mut()));
        self.workflows
            .borrow_mut()
            .insert(app.name().to_string(), rep.clone());
        rep
    }

    /// Memoized campaign under an arbitrary plan (keyed by `key`).
    pub fn campaign(
        &self,
        app: &dyn CrashApp,
        key: &str,
        plan: &PersistPlan,
        verified: bool,
    ) -> Rc<CampaignResult> {
        let full_key = format!("{}::{}{}", app.name(), key, if verified { "::vfy" } else { "" });
        if let Some(c) = self.campaigns.borrow().get(&full_key) {
            return c.clone();
        }
        if self.verbose {
            eprintln!("[campaign] {full_key}");
        }
        let mut runner = self.campaign_runner();
        runner.verified = verified;
        let res = Rc::new(runner.run(app, plan, self.engine.borrow_mut().as_mut()));
        self.campaigns.borrow_mut().insert(full_key, res.clone());
        res
    }

    /// Profile-only run (no crashes) under a plan + optional NVM profile.
    pub fn profile(
        &self,
        app: &dyn CrashApp,
        plan: &PersistPlan,
        cfg: SimConfig,
    ) -> CampaignResult {
        Campaign {
            tests: 0,
            seed: self.seed,
            cfg,
            verified: false,
        }
        .profile(app, plan)
    }

    /// Candidate object names of an app (excluding the iterator bookmark).
    pub fn candidate_names(&self, app: &dyn CrashApp) -> Vec<String> {
        let prof = self.profile(app, &PersistPlan::none(), self.cfg);
        prof.candidates
            .iter()
            .map(|(_, n, _)| n.clone())
            .filter(|n| n != "it")
            .collect()
    }

    /// The paper's three standard plans for an app: none / critical-at-
    /// iteration-end / all-candidates-at-iteration-end.
    pub fn plan_all_candidates(&self, app: &dyn CrashApp) -> PersistPlan {
        let names = self.candidate_names(app);
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        PersistPlan::at_iter_end(&refs, app.regions().len(), 1)
    }

    pub fn plan_critical_iter_end(&self, app: &dyn CrashApp) -> PersistPlan {
        let wf = self.workflow(app);
        let refs: Vec<&str> = wf.critical.iter().map(|s| s.as_str()).collect();
        if refs.is_empty() {
            PersistPlan::none()
        } else {
            PersistPlan::at_iter_end(&refs, app.regions().len(), 1)
        }
    }

    pub fn plan_best(&self, app: &dyn CrashApp) -> PersistPlan {
        let wf = self.workflow(app);
        let refs: Vec<&str> = wf.critical.iter().map(|s| s.as_str()).collect();
        if refs.is_empty() {
            PersistPlan::none()
        } else {
            PersistPlan::at_every_region(&refs, app.regions().len())
        }
    }

    pub fn eval_apps(&self) -> Vec<Box<dyn CrashApp>> {
        apps::eval_set()
    }

    pub fn all_apps(&self) -> Vec<Box<dyn CrashApp>> {
        apps::all()
    }

    /// Average EasyCrash recomputability across the eval set (drives the
    /// §7 model and MTBF_EasyCrash).
    pub fn avg_final_recomputability(&self) -> f64 {
        let apps = self.eval_apps();
        let vals: Vec<f64> = apps
            .iter()
            .map(|a| self.workflow(a.as_ref()).final_result.recomputability())
            .collect();
        crate::util::mean(&vals)
    }
}

//! Shared context for the report generators: configuration + memoized
//! campaigns/workflows so figures that share measurements (Fig. 5/6,
//! Table 1/4...) run each campaign once.
//!
//! The caches are `Mutex<HashMap<_, Arc<_>>>` (not `RefCell`/`Rc`):
//! cached reports are cheap `Arc` clones, and nothing in the context
//! relies on single-threaded interior mutability — only the boxed engine
//! (which may wrap a non-`Send` PJRT client) keeps the context itself
//! pinned to one thread.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::apps::{self, CrashApp};
use crate::easycrash::workflow::{Workflow, WorkflowReport};
use crate::easycrash::{Campaign, CampaignResult, PersistPlan, ShardedCampaign};
use crate::runtime::{NativeEngine, StepEngine};
use crate::sim::SimConfig;
use crate::util::cli::Args;
use crate::util::error::Error;

pub struct ReportCtx {
    pub tests: usize,
    pub seed: u64,
    pub ts: f64,
    pub tau: f64,
    /// Campaign worker threads (`--shards N`). Validated at parse time:
    /// sharding needs one engine per worker, so `> 1` requires the
    /// (default) native engine — same rule as the probe/campaign
    /// subcommands.
    pub shards: usize,
    pub cfg: SimConfig,
    pub verbose: bool,
    engine: Mutex<Box<dyn StepEngine>>,
    workflows: Mutex<HashMap<String, Arc<WorkflowReport>>>,
    campaigns: Mutex<HashMap<String, Arc<CampaignResult>>>,
}

impl ReportCtx {
    pub fn from_args(args: &Args) -> crate::util::error::Result<ReportCtx> {
        let tests = args
            .usize_or("tests", if args.flag("paper-scale") { 1000 } else { 200 })
            .map_err(Error::msg)?;
        let engine_name = args.get_or("engine", "native");
        let engine: Box<dyn StepEngine> = match engine_name {
            "native" => Box::new(NativeEngine::new()),
            "pjrt" => Box::new(crate::runtime::PjrtEngine::from_default_dir()?),
            other => crate::bail!("unknown engine `{other}`"),
        };
        let shards = args.shards_for_engine().map_err(Error::msg)?;
        Ok(ReportCtx {
            tests,
            seed: args.u64_or("seed", 0xEC).map_err(Error::msg)?,
            ts: args.f64_or("ts", 0.03).map_err(Error::msg)?,
            tau: args.f64_or("tau", 0.10).map_err(Error::msg)?,
            shards,
            cfg: SimConfig::mini(),
            verbose: args.flag("verbose"),
            engine: Mutex::new(engine),
            workflows: Mutex::new(HashMap::new()),
            campaigns: Mutex::new(HashMap::new()),
        })
    }

    pub fn campaign_runner(&self) -> Campaign {
        Campaign {
            tests: self.tests,
            seed: self.seed,
            cfg: self.cfg,
            verified: false,
        }
    }

    /// Memoized full workflow for one app.
    pub fn workflow(&self, app: &dyn CrashApp) -> Arc<WorkflowReport> {
        if let Some(w) = self.workflows.lock().unwrap().get(app.name()) {
            return w.clone();
        }
        if self.verbose {
            eprintln!("[workflow] {}", app.name());
        }
        let wf = Workflow {
            tests: self.tests,
            seed: self.seed,
            ts: self.ts,
            tau: self.tau,
            cfg: self.cfg,
        };
        let rep = Arc::new(if self.shards > 1 {
            wf.run_sharded(app, self.shards, &|| Box::new(NativeEngine::new()))
        } else {
            wf.run(app, self.engine.lock().unwrap().as_mut())
        });
        self.workflows
            .lock()
            .unwrap()
            .insert(app.name().to_string(), rep.clone());
        rep
    }

    /// Memoized campaign under an arbitrary plan (keyed by `key`).
    pub fn campaign(
        &self,
        app: &dyn CrashApp,
        key: &str,
        plan: &PersistPlan,
        verified: bool,
    ) -> Arc<CampaignResult> {
        let full_key = format!("{}::{}{}", app.name(), key, if verified { "::vfy" } else { "" });
        if let Some(c) = self.campaigns.lock().unwrap().get(&full_key) {
            return c.clone();
        }
        if self.verbose {
            eprintln!("[campaign] {full_key}");
        }
        let mut runner = self.campaign_runner();
        runner.verified = verified;
        let res = Arc::new(
            ShardedCampaign {
                campaign: runner,
                shards: self.shards,
            }
            .run_or_seq(app, plan, self.engine.lock().unwrap().as_mut()),
        );
        self.campaigns.lock().unwrap().insert(full_key, res.clone());
        res
    }

    /// Profile-only run (no crashes) under a plan + optional NVM profile.
    pub fn profile(
        &self,
        app: &dyn CrashApp,
        plan: &PersistPlan,
        cfg: SimConfig,
    ) -> CampaignResult {
        Campaign {
            tests: 0,
            seed: self.seed,
            cfg,
            verified: false,
        }
        .profile(app, plan)
    }

    /// Candidate object names of an app (excluding the iterator bookmark).
    pub fn candidate_names(&self, app: &dyn CrashApp) -> Vec<String> {
        let prof = self.profile(app, &PersistPlan::none(), self.cfg);
        prof.candidates
            .iter()
            .map(|(_, n, _)| n.clone())
            .filter(|n| n != "it")
            .collect()
    }

    /// The paper's three standard plans for an app: none / critical-at-
    /// iteration-end / all-candidates-at-iteration-end.
    pub fn plan_all_candidates(&self, app: &dyn CrashApp) -> PersistPlan {
        let names = self.candidate_names(app);
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        PersistPlan::at_iter_end(&refs, app.regions().len(), 1)
    }

    pub fn plan_critical_iter_end(&self, app: &dyn CrashApp) -> PersistPlan {
        let wf = self.workflow(app);
        let refs: Vec<&str> = wf.critical.iter().map(|s| s.as_str()).collect();
        if refs.is_empty() {
            PersistPlan::none()
        } else {
            PersistPlan::at_iter_end(&refs, app.regions().len(), 1)
        }
    }

    pub fn plan_best(&self, app: &dyn CrashApp) -> PersistPlan {
        let wf = self.workflow(app);
        let refs: Vec<&str> = wf.critical.iter().map(|s| s.as_str()).collect();
        if refs.is_empty() {
            PersistPlan::none()
        } else {
            PersistPlan::at_every_region(&refs, app.regions().len())
        }
    }

    pub fn eval_apps(&self) -> Vec<Box<dyn CrashApp>> {
        apps::eval_set()
    }

    pub fn all_apps(&self) -> Vec<Box<dyn CrashApp>> {
        apps::all()
    }

    /// Average EasyCrash recomputability across the eval set (drives the
    /// §7 model and MTBF_EasyCrash).
    pub fn avg_final_recomputability(&self) -> f64 {
        let apps = self.eval_apps();
        let vals: Vec<f64> = apps
            .iter()
            .map(|a| self.workflow(a.as_ref()).final_result.recomputability())
            .collect();
        crate::util::mean(&vals)
    }
}

//! Shared context for the report generators: a thin, figure-facing view
//! over [`crate::api::Runner`].
//!
//! All memoization (campaigns, profiles, workflows) lives in the runner,
//! keyed by what is simulated — so figures that share measurements
//! (Fig. 5/6, Table 1/4, the workflow steps...) run each campaign once,
//! and the workflow's step campaigns are the *same* `Arc`s the figures
//! consume. The context only adds the scalar knobs figures read directly
//! (`tests`, `ts`, `tau`, `cfg`, ...) and the paper's three standard
//! plan constructors.

use std::sync::Arc;

use crate::api::{ExperimentSpec, Runner};
use crate::apps::{self, CrashApp};
use crate::easycrash::workflow::WorkflowReport;
use crate::easycrash::{CampaignResult, PersistPlan};
use crate::sim::SimConfig;
use crate::util::cli::Args;
use crate::util::error::Result;

pub struct ReportCtx {
    pub tests: usize,
    pub seed: u64,
    pub ts: f64,
    pub tau: f64,
    /// Campaign worker threads (`--shards N`). Validated at spec-build
    /// time: sharding needs one engine per worker, so `> 1` requires the
    /// (default) native engine — same rule as every other subcommand.
    pub shards: usize,
    pub cfg: SimConfig,
    /// `--trace`: add the Monte Carlo simulated-efficiency column to
    /// fig10/fig11 (same `model::trace` pipeline as the `efficiency`
    /// subcommand, at a report-friendly trial count).
    pub with_trace: bool,
    runner: Runner,
}

impl ReportCtx {
    pub fn from_args(args: &Args) -> Result<ReportCtx> {
        let spec = ExperimentSpec::from_args(args)?;
        // Figures read through the durable store like every other
        // consumer (`--no-store` opts out), so regenerating a report
        // after a restart reuses every previously simulated cell.
        let runner = Runner::new(spec)?
            .verbose(args.flag("verbose"))
            .with_store(crate::store::from_args(args)?);
        let s = runner.spec();
        Ok(ReportCtx {
            tests: s.tests,
            seed: s.seed,
            ts: s.ts,
            tau: s.tau,
            shards: s.shards,
            cfg: s.cfg,
            with_trace: args.flag("trace"),
            runner,
        })
    }

    /// The underlying unified runner.
    pub fn runner(&self) -> &Runner {
        &self.runner
    }

    /// Memoized full workflow for one app (under the spec's planner —
    /// `--planner` swaps the strategy pair for every figure at once).
    pub fn workflow(&self, app: &dyn CrashApp) -> Result<Arc<WorkflowReport>> {
        self.runner.workflow(app)
    }

    /// Memoized campaign under an arbitrary plan (keyed by the plan's
    /// canonical DSL).
    pub fn campaign(
        &self,
        app: &dyn CrashApp,
        plan: &PersistPlan,
        verified: bool,
    ) -> Result<Arc<CampaignResult>> {
        self.runner.campaign(app, plan, verified)
    }

    /// Memoized profile-only run (no crashes) under a plan + optional
    /// NVM profile.
    pub fn profile(
        &self,
        app: &dyn CrashApp,
        plan: &PersistPlan,
        cfg: SimConfig,
    ) -> Result<Arc<CampaignResult>> {
        self.runner.profile(app, plan, cfg)
    }

    /// Candidate object names of an app (excluding the iterator bookmark).
    pub fn candidate_names(&self, app: &dyn CrashApp) -> Result<Vec<String>> {
        self.runner.candidate_names(app)
    }

    /// The paper's three standard plans for an app: none / critical-at-
    /// iteration-end / all-candidates-at-iteration-end.
    pub fn plan_all_candidates(&self, app: &dyn CrashApp) -> Result<PersistPlan> {
        self.runner.plan_all_candidates(app)
    }

    pub fn plan_critical_iter_end(&self, app: &dyn CrashApp) -> Result<PersistPlan> {
        self.runner.plan_critical_iter_end(app)
    }

    pub fn plan_best(&self, app: &dyn CrashApp) -> Result<PersistPlan> {
        self.runner.plan_best(app)
    }

    pub fn eval_apps(&self) -> Vec<Box<dyn CrashApp>> {
        apps::eval_set()
    }

    pub fn all_apps(&self) -> Vec<Box<dyn CrashApp>> {
        apps::all()
    }

    /// Average EasyCrash recomputability across the eval set (drives the
    /// §7 model and MTBF_EasyCrash).
    pub fn avg_final_recomputability(&self) -> Result<f64> {
        let apps = self.eval_apps();
        let vals: Vec<f64> = apps
            .iter()
            .map(|a| {
                self.workflow(a.as_ref())
                    .map(|wf| wf.final_result.recomputability())
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(crate::util::mean(&vals))
    }
}

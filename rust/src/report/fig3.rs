//! Figure 3: application responses after crash and restart, with no
//! persistence (S1 = success, S2 = success w/ extra iterations, S3 =
//! interruption, S4 = verification fails).

use crate::easycrash::PersistPlan;
use crate::util::{pct, table::Table};

use super::context::ReportCtx;

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let mut t = Table::new(&["app", "S1", "S2", "S3", "S4"]);
    let mut sums = [0.0; 4];
    let apps = ctx.all_apps();
    for app in &apps {
        let r = ctx.campaign(app.as_ref(), &PersistPlan::none(), false)?;
        let f = r.response_fractions();
        for (s, x) in sums.iter_mut().zip(f) {
            *s += x;
        }
        t.row(vec![
            app.name().into(),
            pct(f[0]),
            pct(f[1]),
            pct(f[2]),
            pct(f[3]),
        ]);
    }
    let n = apps.len() as f64;
    t.row(vec![
        "average".into(),
        pct(sums[0] / n),
        pct(sums[1] / n),
        pct(sums[2] / n),
        pct(sums[3] / n),
    ]);
    Ok(t)
}

//! Figure 4: MG's recomputability (a) persisting individual data objects
//! at the end of each main-loop iteration, and (b) persisting `u` at the
//! end of each of the four code regions R1–R4.

use crate::easycrash::PersistPlan;
use crate::util::{pct, table::Table};

use super::context::ReportCtx;

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<(Table, Table)> {
    let app = crate::apps::by_name("mg").expect("mg registered");
    let regions = app.regions().len();

    // (a) persist one object at a time at iteration end.
    let mut ta = Table::new(&["persisted object", "recomputability"]);
    let base = ctx.campaign(app.as_ref(), &PersistPlan::none(), false)?;
    ta.row(vec!["none".into(), pct(base.recomputability())]);
    for obj in ["it", "u", "r"] {
        let plan = PersistPlan::at_iter_end(&[obj], regions, 1);
        let r = ctx.campaign(app.as_ref(), &plan, false)?;
        ta.row(vec![obj.into(), pct(r.recomputability())]);
    }

    // (b) persist u at the end of each region.
    let mut tb = Table::new(&["persist u at", "recomputability"]);
    tb.row(vec!["none".into(), pct(base.recomputability())]);
    let names: Vec<String> = app.regions().iter().map(|r| r.name.to_string()).collect();
    for k in 0..regions {
        let plan = PersistPlan::at_region(&["u"], k, 1);
        let r = ctx.campaign(app.as_ref(), &plan, false)?;
        tb.row(vec![format!("R{} ({})", k + 1, names[k]), pct(r.recomputability())]);
    }
    Ok((ta, tb))
}

//! Figure 11: system efficiency for CG as the system scales from 100k to
//! 200k and 400k nodes (MTBF 12 h → 6 h → 3 h). With `--trace`, an extra
//! column cross-checks the closed form against the `model::trace` Monte
//! Carlo simulator at CG's measured recomputability.

use crate::model::efficiency::{evaluate, t_r_nvm_seconds, EfficiencyInput};
use crate::model::sweep::{SCALES, T_CHK_SCENARIOS};
use crate::util::{pct, table::Table};

use super::context::ReportCtx;
use super::fig10::simulated_ec;

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let cg = crate::apps::by_name("cg").expect("cg registered");
    let r = ctx.workflow(cg.as_ref())?.final_result.recomputability();
    let t_r_nvm = t_r_nvm_seconds(96e9);
    let mut cols: Vec<&str> = vec!["nodes", "MTBF", "T_chk", "base", "EasyCrash", "improve"];
    if ctx.with_trace {
        cols.push("EasyCrash (sim)");
    }
    let mut t = Table::new(&cols);
    for &(nodes, mtbf) in &SCALES {
        for &t_chk in &T_CHK_SCENARIOS {
            let m = evaluate(&EfficiencyInput::paper(mtbf, t_chk, r, ctx.ts, t_r_nvm)?)?;
            let mut row = vec![
                nodes.to_string(),
                format!("{:.0}h", mtbf / 3600.0),
                format!("{t_chk:.0}s"),
                pct(m.base),
                pct(m.easycrash),
                pct(m.improvement()),
            ];
            if ctx.with_trace {
                row.push(pct(simulated_ec(ctx, mtbf, t_chk, r, t_r_nvm)?));
            }
            t.row(row);
        }
    }
    println!("CG R_EasyCrash = {} (improvement grows with scale, as in the paper)", pct(r));
    Ok(t)
}

//! Figure 11: system efficiency for CG as the system scales from 100k to
//! 200k and 400k nodes (MTBF 12 h → 6 h → 3 h).

use crate::model::efficiency::{evaluate, EfficiencyInput};
use crate::model::sweep::{SCALES, T_CHK_SCENARIOS};
use crate::util::{pct, table::Table};

use super::context::ReportCtx;
use super::fig10::t_r_nvm_seconds;

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let cg = crate::apps::by_name("cg").expect("cg registered");
    let r = ctx.workflow(cg.as_ref()).final_result.recomputability();
    let t_r_nvm = t_r_nvm_seconds(96e9);
    let mut t = Table::new(&["nodes", "MTBF", "T_chk", "base", "EasyCrash", "improve"]);
    for &(nodes, mtbf) in &SCALES {
        for &t_chk in &T_CHK_SCENARIOS {
            let m = evaluate(&EfficiencyInput::paper(mtbf, t_chk, r, ctx.ts, t_r_nvm));
            t.row(vec![
                nodes.to_string(),
                format!("{:.0}h", mtbf / 3600.0),
                format!("{t_chk:.0}s"),
                pct(m.base),
                pct(m.easycrash),
                pct(m.improvement()),
            ]);
        }
    }
    println!("CG R_EasyCrash = {} (improvement grows with scale, as in the paper)", pct(r));
    Ok(t)
}

//! Figure 9: number of NVM writes, normalized by the baseline run's
//! writes — EasyCrash's flush-induced extra writes vs traditional C/R
//! checkpointing of (a) critical objects only and (b) all candidate
//! objects. The checkpoint copy is *simulated through the cache
//! hierarchy* (reads pull checkpoint data through the caches, evicting
//! dirty lines — the paper's point about C/R's collateral writes), and
//! the checkpoint region is flushed to NVM once (the paper's
//! conservative single-checkpoint assumption).

use crate::apps::CrashApp;
use crate::easycrash::PersistPlan;
use crate::sim::{Env, FlushKind, ObjSpec, SimEnv};
use crate::util::{mean, table::Table};

use super::context::ReportCtx;

/// Run the app with no persistence, then simulate one checkpoint of the
/// named objects; return (baseline_writes, writes_with_checkpoint).
fn checkpoint_writes(ctx: &ReportCtx, app: &dyn CrashApp, objects: &[String]) -> (u64, u64) {
    let mut env = SimEnv::new(&ctx.cfg, app.regions().len());
    app.run_sim(&mut env).expect("profile run");
    let w0 = env.hier.stats.nvm_writes();
    // Copy each object line-by-line through the caches into a shadow
    // checkpoint area, then persist the checkpoint with CLFLUSHOPT.
    let ids: Vec<_> = objects
        .iter()
        .filter_map(|n| env.reg.by_name(n))
        .collect();
    for id in ids {
        let (base, bytes) = {
            let o = env.reg.get(id);
            (o.base, o.spec.bytes())
        };
        let lines = (bytes + 63) / 64;
        let chk = env.alloc(ObjSpec::f64(
            "__chk",
            lines * 8, // one line's worth of f64 per source line
            false,
        ));
        let chk_base = env.reg.get(chk.id).base;
        for l in 0..lines {
            let src = base + l * 64;
            let dst = chk_base + l * 64;
            // Read the source line, write the checkpoint line (both
            // through the hierarchy: this is what evicts dirty data).
            let c1 = env.hier.access(&mut env.mem, src, false);
            let c2 = env.hier.access(&mut env.mem, dst, true);
            env.clock.add(app.regions().len(), c1 + c2);
        }
        env.hier
            .flush_range(&mut env.mem, chk_base, lines * 64, FlushKind::ClflushOpt);
    }
    (w0, env.hier.stats.nvm_writes())
}

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let mut t = Table::new(&[
        "app",
        "baseline writes",
        "EC extra",
        "C/R critical extra",
        "C/R all extra",
    ]);
    let (mut ecs, mut crits, mut alls) = (Vec::new(), Vec::new(), Vec::new());
    for app in ctx.eval_apps() {
        let wf = ctx.workflow(app.as_ref())?;
        let base = ctx.profile(app.as_ref(), &PersistPlan::none(), ctx.cfg)?;
        let w0 = base.stats.nvm_writes().max(1);
        let ec = ctx.profile(app.as_ref(), &wf.plan, ctx.cfg)?;
        let ec_extra = ec.stats.nvm_writes().saturating_sub(w0) as f64 / w0 as f64;

        let crit_names: Vec<String> = wf.critical.clone();
        let all_names: Vec<String> = ctx.candidate_names(app.as_ref())?;
        let (b1, w1) = checkpoint_writes(ctx, app.as_ref(), &crit_names);
        let (b2, w2) = checkpoint_writes(ctx, app.as_ref(), &all_names);
        let cr_crit = (w1 - b1) as f64 / b1.max(1) as f64;
        let cr_all = (w2 - b2) as f64 / b2.max(1) as f64;
        ecs.push(ec_extra);
        crits.push(cr_crit);
        alls.push(cr_all);
        t.row(vec![
            app.name().into(),
            w0.to_string(),
            format!("{:.1}%", ec_extra * 100.0),
            format!("{:.1}%", cr_crit * 100.0),
            format!("{:.1}%", cr_all * 100.0),
        ]);
    }
    t.row(vec![
        "average".into(),
        "-".into(),
        format!("{:.1}%", mean(&ecs) * 100.0),
        format!("{:.1}%", mean(&crits) * 100.0),
        format!("{:.1}%", mean(&alls) * 100.0),
    ]);
    let red = 1.0 - mean(&ecs) / mean(&crits).max(1e-9);
    println!(
        "EasyCrash adds {:.0}% writes vs C/R-critical {:.0}% and C/R-all {:.0}% (paper: 16% vs 38%/50%); reduction vs C/R: {:.0}% (paper avg 44%)",
        mean(&ecs) * 100.0,
        mean(&crits) * 100.0,
        mean(&alls) * 100.0,
        red * 100.0
    );
    Ok(t)
}

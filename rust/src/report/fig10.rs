//! Figure 10: system efficiency with and without EasyCrash at MTBF = 12 h
//! under the three checkpoint-overhead scenarios (32 s / 320 s / 3200 s),
//! for the lowest- and highest-recomputability benchmarks plus the
//! average (the paper shows FT, SP and the average). With `--trace`, an
//! extra column cross-checks the closed form against the `model::trace`
//! Monte Carlo simulator at the average recomputability.

use crate::model::efficiency::{evaluate, t_r_nvm_seconds, EfficiencyInput};
use crate::model::sweep::T_CHK_SCENARIOS;
use crate::model::trace::{FailureDist, RecoveryPolicy, TraceInput, TraceSim, DEFAULT_WORK};
use crate::util::{pct, table::Table};

use super::context::ReportCtx;
use super::fig6;

/// Monte Carlo volume of the report columns: far above visual resolution
/// (SE ≈ 0.1%) while keeping `--trace` report latency in milliseconds;
/// the `efficiency` subcommand runs the full `DEFAULT_TRIALS`.
pub(super) const SIM_TRIALS: usize = 2_000;

/// Simulated EasyCrash efficiency at one model point — the same pipeline
/// as the `efficiency` subcommand (Exponential failures, Young interval,
/// trials sharded over RNG lanes with the report's `--shards`).
pub(super) fn simulated_ec(
    ctx: &ReportCtx,
    mtbf: f64,
    t_chk: f64,
    r: f64,
    t_r_nvm: f64,
) -> crate::util::error::Result<f64> {
    let model = EfficiencyInput::paper(mtbf, t_chk, r, ctx.ts, t_r_nvm)?;
    let sim = TraceSim {
        trials: SIM_TRIALS,
        seed: ctx.seed,
        shards: ctx.shards,
    };
    Ok(sim
        .run(&TraceInput {
            model,
            policy: RecoveryPolicy::EasyCrashPlusCheckpoint,
            dist: FailureDist::Exponential,
            work: DEFAULT_WORK,
            interval: None,
        })?
        .mean_efficiency)
}

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let rows = fig6::rows(ctx)?;
    let lo = rows
        .iter()
        .min_by(|a, b| a.easycrash.total_cmp(&b.easycrash))
        .expect("rows");
    let hi = rows
        .iter()
        .max_by(|a, b| a.easycrash.total_cmp(&b.easycrash))
        .expect("rows");
    let avg = crate::util::mean(&rows.iter().map(|r| r.easycrash).collect::<Vec<_>>());
    // Model a 96 GB node (paper's 64-128 GB) for the NVM restart time.
    let t_r_nvm = t_r_nvm_seconds(96e9);
    let mtbf = 12.0 * 3600.0;

    let lo_base = format!("{} base", lo.app);
    let lo_ec = format!("{} EC", lo.app);
    let hi_base = format!("{} base", hi.app);
    let hi_ec = format!("{} EC", hi.app);
    let mut cols: Vec<&str> = vec![
        "T_chk", &lo_base, &lo_ec, &hi_base, &hi_ec, "avg base", "avg EC", "avg improve",
    ];
    if ctx.with_trace {
        cols.push("avg EC (sim)");
    }
    let mut t = Table::new(&cols);
    for &t_chk in &T_CHK_SCENARIOS {
        let m_lo = evaluate(&EfficiencyInput::paper(mtbf, t_chk, lo.easycrash, ctx.ts, t_r_nvm)?)?;
        let m_hi = evaluate(&EfficiencyInput::paper(mtbf, t_chk, hi.easycrash, ctx.ts, t_r_nvm)?)?;
        let m_av = evaluate(&EfficiencyInput::paper(mtbf, t_chk, avg, ctx.ts, t_r_nvm)?)?;
        let mut row = vec![
            format!("{t_chk:.0}s"),
            pct(m_lo.base),
            pct(m_lo.easycrash),
            pct(m_hi.base),
            pct(m_hi.easycrash),
            pct(m_av.base),
            pct(m_av.easycrash),
            pct(m_av.improvement()),
        ];
        if ctx.with_trace {
            row.push(pct(simulated_ec(ctx, mtbf, t_chk, avg, t_r_nvm)?));
        }
        t.row(row);
    }
    println!(
        "lowest-recomputability app: {} (R={}), highest: {} (R={}); paper shows FT and SP",
        lo.app,
        pct(lo.easycrash),
        hi.app,
        pct(hi.easycrash)
    );
    Ok(t)
}

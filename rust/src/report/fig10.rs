//! Figure 10: system efficiency with and without EasyCrash at MTBF = 12 h
//! under the three checkpoint-overhead scenarios (32 s / 320 s / 3200 s),
//! for the lowest- and highest-recomputability benchmarks plus the
//! average (the paper shows FT, SP and the average).

use crate::model::efficiency::{evaluate, EfficiencyInput};
use crate::model::sweep::T_CHK_SCENARIOS;
use crate::util::{pct, table::Table};

use super::context::ReportCtx;
use super::fig6;

/// NVM restart time: non-read-only data / DRAM bandwidth (§7 T_r').
pub fn t_r_nvm_seconds(bytes_per_node: f64) -> f64 {
    bytes_per_node / 106e9
}

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let rows = fig6::rows(ctx);
    let lo = rows
        .iter()
        .min_by(|a, b| a.easycrash.total_cmp(&b.easycrash))
        .expect("rows");
    let hi = rows
        .iter()
        .max_by(|a, b| a.easycrash.total_cmp(&b.easycrash))
        .expect("rows");
    let avg = crate::util::mean(&rows.iter().map(|r| r.easycrash).collect::<Vec<_>>());
    // Model a 96 GB node (paper's 64-128 GB) for the NVM restart time.
    let t_r_nvm = t_r_nvm_seconds(96e9);
    let mtbf = 12.0 * 3600.0;

    let mut t = Table::new(&[
        "T_chk",
        &format!("{} base", lo.app),
        &format!("{} EC", lo.app),
        &format!("{} base", hi.app),
        &format!("{} EC", hi.app),
        "avg base",
        "avg EC",
        "avg improve",
    ]);
    for &t_chk in &T_CHK_SCENARIOS {
        let m_lo = evaluate(&EfficiencyInput::paper(mtbf, t_chk, lo.easycrash, ctx.ts, t_r_nvm));
        let m_hi = evaluate(&EfficiencyInput::paper(mtbf, t_chk, hi.easycrash, ctx.ts, t_r_nvm));
        let m_av = evaluate(&EfficiencyInput::paper(mtbf, t_chk, avg, ctx.ts, t_r_nvm));
        t.row(vec![
            format!("{t_chk:.0}s"),
            pct(m_lo.base),
            pct(m_lo.easycrash),
            pct(m_hi.base),
            pct(m_hi.easycrash),
            pct(m_av.base),
            pct(m_av.easycrash),
            pct(m_av.improvement()),
        ]);
    }
    println!(
        "lowest-recomputability app: {} (R={}), highest: {} (R={}); paper shows FT and SP",
        lo.app,
        pct(lo.easycrash),
        hi.app,
        pct(hi.easycrash)
    );
    Ok(t)
}

//! Table 4: runtime overhead — persist-once time, number of persistence
//! operations, and normalized execution time with EasyCrash, without
//! selection (all candidates every iteration), and for the best-
//! recomputability configuration. Times come from the simulator's
//! cycle-accurate* event model at 2.6 GHz (*per-event analytical costs;
//! see sim/timing.rs), so ratios are the meaningful output.

use crate::easycrash::PersistPlan;
use crate::util::table::Table;

use super::context::ReportCtx;

pub struct T4Row {
    pub app: String,
    pub persist_once_s: f64,
    pub persist_ops: u64,
    pub norm_ec: f64,
    pub norm_all: f64,
    pub norm_best: f64,
}

pub fn rows(ctx: &ReportCtx) -> crate::util::error::Result<Vec<T4Row>> {
    let mut out = Vec::new();
    for app in ctx.eval_apps() {
        let base = ctx.profile(app.as_ref(), &PersistPlan::none(), ctx.cfg)?;
        let wf = ctx.workflow(app.as_ref())?;
        let ec = ctx.profile(app.as_ref(), &wf.plan, ctx.cfg)?;
        let all = ctx.profile(app.as_ref(), &ctx.plan_all_candidates(app.as_ref())?, ctx.cfg)?;
        let best = ctx.profile(app.as_ref(), &ctx.plan_best(app.as_ref())?, ctx.cfg)?;
        let persist_once = if ec.persist_ops > 0 {
            ec.persist_cycles / ec.persist_ops as f64 / 2.6e9
        } else {
            0.0
        };
        out.push(T4Row {
            app: app.name().to_string(),
            persist_once_s: persist_once,
            persist_ops: ec.persist_ops,
            norm_ec: ec.cycles / base.cycles,
            norm_all: all.cycles / base.cycles,
            norm_best: best.cycles / base.cycles,
        });
    }
    Ok(out)
}

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let rows = rows(ctx)?;
    let mut t = Table::new(&[
        "app",
        "persist once",
        "#persist ops",
        "norm time (EC)",
        "norm time (all cand.)",
        "norm time (best)",
    ]);
    let (mut se, mut sa, mut sb) = (0.0, 0.0, 0.0);
    for r in &rows {
        se += r.norm_ec;
        sa += r.norm_all;
        sb += r.norm_best;
        t.row(vec![
            r.app.clone(),
            if r.persist_once_s < 1e-6 {
                "<1us".into()
            } else {
                format!("{:.1}us", r.persist_once_s * 1e6)
            },
            r.persist_ops.to_string(),
            format!("{:.3}", r.norm_ec),
            format!("{:.3}", r.norm_all),
            format!("{:.3}", r.norm_best),
        ]);
    }
    let n = rows.len() as f64;
    t.row(vec![
        "average".into(),
        "-".into(),
        "-".into(),
        format!("{:.3}", se / n),
        format!("{:.3}", sa / n),
        format!("{:.3}", sb / n),
    ]);
    println!(
        "EC overhead avg {:.1}% (paper: 1.5%, bound t_s={:.0}%); all-candidates {:.0}% (paper 19%); best {:.0}% (paper 35%)",
        (se / n - 1.0) * 100.0,
        ctx.ts * 100.0,
        (sa / n - 1.0) * 100.0,
        (sb / n - 1.0) * 100.0
    );
    Ok(t)
}

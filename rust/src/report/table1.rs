//! Table 1: benchmark information for the crash experiments.

use crate::easycrash::selection::critical_bytes;
use crate::easycrash::PersistPlan;
use crate::util::{human_bytes, table::Table};

use super::context::ReportCtx;

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let mut t = Table::new(&[
        "app",
        "#regions",
        "R/W",
        "footprint",
        "candidate DO",
        "critical DO",
        "extra iter (restart)",
        "#iters",
    ]);
    for app in ctx.all_apps() {
        let base = ctx.campaign(app.as_ref(), &PersistPlan::none(), false)?;
        let loads = base.stats.loads.max(1);
        let stores = base.stats.stores.max(1);
        let ratio = if loads >= stores {
            format!("{:.0}:1", loads as f64 / stores as f64)
        } else {
            format!("1:{:.0}", stores as f64 / loads as f64)
        };
        // Candidate size excludes the iterator bookmark by its resolved
        // object id (same rule as selection — never the literal name).
        let cand_bytes: usize = base.selectable_candidates().map(|(_, _, b)| *b).sum();
        // Critical DO size: EP is excluded from the EasyCrash evaluation
        // (its selection finds nothing usable, §6/§8).
        let crit = if app.name() == "ep" {
            "n/a".to_string()
        } else {
            let wf = ctx.workflow(app.as_ref())?;
            human_bytes(critical_bytes(&wf.selection) as u64)
        };
        // "Ave. # of extra iter. to restart": the paper reports N/A with
        // the dominant failure class when restart doesn't succeed.
        let f = base.response_fractions();
        let extra = if let Some(e) = base.mean_extra_iters() {
            format!("{e:.1}")
        } else if f[2] > f[3] && f[2] > 0.1 {
            "N/A (segfault)".to_string()
        } else if f[3] > 0.1 {
            "N/A (verification fails)".to_string()
        } else {
            "0".to_string()
        };
        t.row(vec![
            app.name().into(),
            app.regions().len().to_string(),
            ratio,
            human_bytes(base.footprint as u64),
            human_bytes(cand_bytes as u64),
            crit,
            extra,
            app.nominal_iters().to_string(),
        ]);
    }
    Ok(t)
}

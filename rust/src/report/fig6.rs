//! Figure 6: application recomputability with different methods —
//! without EasyCrash, + selecting data objects, + selecting code regions
//! (the full EasyCrash), the costly "best" configuration, and the
//! physical-machine "verified" methodology.

use crate::easycrash::PersistPlan;
use crate::util::{mean, pct, table::Table};

use super::context::ReportCtx;

pub struct Fig6Row {
    pub app: String,
    pub base: f64,
    pub select_do: f64,
    pub easycrash: f64,
    pub best: f64,
    pub verified: f64,
}

pub fn rows(ctx: &ReportCtx) -> crate::util::error::Result<Vec<Fig6Row>> {
    let mut out = Vec::new();
    for app in ctx.eval_apps() {
        let wf = ctx.workflow(app.as_ref())?;
        let sel_plan = ctx.plan_critical_iter_end(app.as_ref())?;
        let sel = ctx.campaign(app.as_ref(), &sel_plan, false)?;
        let vfy = ctx.campaign(app.as_ref(), &PersistPlan::none(), true)?;
        out.push(Fig6Row {
            app: app.name().to_string(),
            base: wf.base.recomputability(),
            select_do: sel.recomputability(),
            easycrash: wf.final_result.recomputability(),
            best: wf.best.recomputability(),
            verified: vfy.recomputability(),
        });
    }
    Ok(out)
}

pub fn run(ctx: &ReportCtx) -> crate::util::error::Result<Table> {
    let rows = rows(ctx)?;
    let mut t = Table::new(&["app", "w/o EC", "+select DOs", "EC (full)", "best", "VFY"]);
    for r in &rows {
        t.row(vec![
            r.app.clone(),
            pct(r.base),
            pct(r.select_do),
            pct(r.easycrash),
            pct(r.best),
            pct(r.verified),
        ]);
    }
    let avg = |f: fn(&Fig6Row) -> f64| mean(&rows.iter().map(f).collect::<Vec<_>>());
    t.row(vec![
        "average".into(),
        pct(avg(|r| r.base)),
        pct(avg(|r| r.select_do)),
        pct(avg(|r| r.easycrash)),
        pct(avg(|r| r.best)),
        pct(avg(|r| r.verified)),
    ]);
    // Headline: fraction of previously-failing crashes EasyCrash converts.
    let b = avg(|r| r.base);
    let e = avg(|r| r.easycrash);
    if b < 1.0 {
        println!(
            "transformed {} of previously-failing crashes into correct recomputation (paper: 54%)",
            pct((e - b) / (1.0 - b))
        );
    }
    println!(
        "average recomputability: {} -> {} with EasyCrash (paper: 28% -> 82%)",
        pct(b),
        pct(e)
    );
    Ok(t)
}

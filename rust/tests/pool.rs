//! Durable-pool engine acceptance: the crash matrix where the simulated
//! campaign and the pool engine must agree record-by-record, pinned
//! flush-boundary kills, recovery over damaged pool files (typed cold
//! starts, never panics) and the `--engine pool` spec round-trip.

use std::path::{Path, PathBuf};

use easycrash::api::{EngineKind, ExperimentSpec, Runner};
use easycrash::apps::{self, CrashApp};
use easycrash::easycrash::killcampaign::resolve_plan_basic;
use easycrash::easycrash::{Campaign, KillCampaign, PersistPlan, PlanSpec};
use easycrash::runtime::NativeEngine;
use easycrash::sim::{ColdStartReason, PoolEnv, RecoveryOutcome, Signal, SimConfig, SimEnv};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("easycrash-pooltest-{}-{name}.pool", std::process::id()))
}

/// The iteration counter the app would report if halted at op `p` —
/// monotone in `p`, so a binary search over it finds the exact op at
/// which an iteration (and with it the plan's iteration-end flush)
/// completes.
fn iter_at(app: &dyn CrashApp, plan: &PersistPlan, p: u64) -> u64 {
    let probe = app.probe_layout().unwrap();
    let num_regions = app.regions().len();
    let hooks = plan.resolve_for(&probe.reg, num_regions, probe.iter_obj).unwrap();
    let mut env = SimEnv::new(&SimConfig::mini(), num_regions);
    env.set_hooks(hooks);
    env.halt_at = Some(p);
    match app.run_sim(&mut env) {
        Err(Signal::Crash) => env.cur_iter(),
        other => panic!("expected a halt at op {p}, got {other:?}"),
    }
}

/// Leave a *dirty* pool file behind, as a killed process would: begin a
/// run, halt mid-flight, drop everything without `finish_run`. Returns
/// the generation the run wrote.
fn dirty_pool(path: &Path, app: &dyn CrashApp, plan: &PersistPlan, halt: u64) -> u64 {
    let probe = app.probe_layout().unwrap();
    let num_regions = app.regions().len();
    let hooks = plan.resolve_for(&probe.reg, num_regions, probe.iter_obj).unwrap();
    let mut pool =
        PoolEnv::create(path, app.name(), &probe.reg, probe.iter_obj, num_regions).unwrap();
    pool.begin_run().unwrap();
    let generation = pool.generation();
    let mut env = SimEnv::new(&SimConfig::mini(), num_regions);
    env.set_hooks(hooks);
    pool.attach(&mut env).unwrap();
    env.halt_at = Some(halt);
    assert!(matches!(app.run_sim(&mut env), Err(Signal::Crash)));
    generation
}

// -- crash-matrix parity ----------------------------------------------------

/// The ISSUE's acceptance matrix: 3 apps x 2 plans, seeded kill points;
/// the simulated engine (discard dirty lines, keep running) and the pool
/// engine (write-through file, real two-phase restart) must produce
/// identical records — op, iter, region, response class, extra
/// iterations and the per-candidate inconsistency bits.
#[test]
fn pool_and_simulated_engines_agree_on_the_crash_matrix() {
    for app_name in ["toy", "adi", "fft"] {
        let app = apps::by_name(app_name).unwrap();
        let app = app.as_ref();
        for plan_dsl in ["none", "all"] {
            let plan = resolve_plan_basic(app, plan_dsl).unwrap();
            let kc = KillCampaign { tests: 4, seed: 0x5EED, ..KillCampaign::default() };
            let sim =
                Campaign { tests: kc.tests, seed: kc.seed, cfg: kc.cfg, ..Campaign::default() };
            let mut engine = NativeEngine::new();
            let simulated = sim.run(app, &plan, &mut engine).unwrap();
            let pool_path = tmp(&format!("matrix-{app_name}-{plan_dsl}"));
            let pooled = kc.run_in_process(app, &plan, &pool_path, &mut engine).unwrap();
            assert_eq!(
                simulated.records, pooled.records,
                "simulated vs pool disagree for {app_name}/{plan_dsl}"
            );
            assert!(!pool_path.exists(), "campaign must clean up its pool file");
        }
    }
}

/// Kills pinned to an exact flush boundary: one op before the last op of
/// an iteration, at it, and one op after the iteration-end flush. Both
/// engines must classify all three identically.
#[test]
fn flush_boundary_kills_agree_between_engines() {
    let app = apps::by_name("toy").unwrap();
    let app = app.as_ref();
    let plan = resolve_plan_basic(app, "all").unwrap();
    let kc = KillCampaign { tests: 3, seed: 0xB0B, ..KillCampaign::default() };
    let sim = Campaign { tests: kc.tests, seed: kc.seed, cfg: kc.cfg, ..Campaign::default() };
    let profile = sim.profile(app, &plan).unwrap();
    // Find the smallest op at which the first main-loop iteration has
    // completed (and its iteration-end flush has run).
    let target = iter_at(app, &plan, profile.ops_main_start + 1) + 1;
    let (mut lo, mut hi) = (profile.ops_main_start + 1, profile.ops_total - 1);
    assert!(iter_at(app, &plan, hi) >= target, "run must span an iteration end");
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if iter_at(app, &plan, mid) >= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let boundary = lo;
    let points = vec![boundary - 1, boundary, boundary + 1];
    let mut engine = NativeEngine::new();
    let simulated = sim.run_at(app, &plan, points.clone(), &mut engine).unwrap();
    let pool_path = tmp("boundary");
    let pooled = kc.run_in_process_at(app, &plan, points, &pool_path, &mut engine).unwrap();
    assert_eq!(simulated.records, pooled.records);
    // The probe really straddles the boundary: the iteration counter
    // differs across the three records.
    assert!(simulated.records[0].iter < simulated.records[2].iter);
}

// -- recovery edge cases (never panic, always typed) ------------------------

#[test]
fn recovery_degrades_gracefully_on_damaged_pools() {
    let app = apps::by_name("toy").unwrap();
    let app = app.as_ref();
    let probe = app.probe_layout().unwrap();
    let num_regions = app.regions().len();
    let open = |path: &Path| PoolEnv::open(path, "toy", &probe.reg, probe.iter_obj, num_regions);
    let path = tmp("damage");

    // Missing file: a first boot, not an error.
    let _ = std::fs::remove_file(&path);
    let (_, outcome) = open(&path).unwrap();
    assert!(matches!(outcome, RecoveryOutcome::ColdStart(ColdStartReason::NoPool)));

    // Zero-length pool file.
    std::fs::write(&path, b"").unwrap();
    let (_, outcome) = open(&path).unwrap();
    assert!(matches!(outcome, RecoveryOutcome::ColdStart(ColdStartReason::EmptyPool)));

    // Header truncated mid-field.
    std::fs::write(&path, b"ECPL\x01\x00\x00").unwrap();
    let (_, outcome) = open(&path).unwrap();
    assert!(matches!(
        outcome,
        RecoveryOutcome::ColdStart(ColdStartReason::TruncatedHeader { len: 7 })
    ));

    // A genuinely dirty pool, then: generation pinning, version skew and
    // checksum damage, each a typed cold start (or skew error path) with
    // no panic.
    let generation = dirty_pool(&path, app, &plan_all(app), 20_000);
    assert_eq!(generation, 1);
    let (_, outcome) = PoolEnv::open_expecting(
        &path,
        "toy",
        &probe.reg,
        probe.iter_obj,
        num_regions,
        Some(999),
    )
    .unwrap();
    assert!(matches!(
        outcome,
        RecoveryOutcome::ColdStart(ColdStartReason::GenerationSkew { expected: 999, found: 1 })
    ));

    dirty_pool(&path, app, &plan_all(app), 20_000);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[4] = 99; // version field
    std::fs::write(&path, &bytes).unwrap();
    let (_, outcome) = open(&path).unwrap();
    assert!(matches!(
        outcome,
        RecoveryOutcome::ColdStart(ColdStartReason::VersionSkew { found: 99 })
    ));

    dirty_pool(&path, app, &plan_all(app), 20_000);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[40] ^= 0xFF; // inside the checksummed header body
    std::fs::write(&path, &bytes).unwrap();
    let (_, outcome) = open(&path).unwrap();
    assert!(matches!(outcome, RecoveryOutcome::ColdStart(ColdStartReason::BadChecksum)));

    // And a dirty pool opened under a *different* app's layout.
    dirty_pool(&path, app, &plan_all(app), 20_000);
    let other = apps::by_name("adi").unwrap();
    let oprobe = other.probe_layout().unwrap();
    let (_, outcome) = PoolEnv::open(
        &path,
        "adi",
        &oprobe.reg,
        oprobe.iter_obj,
        other.regions().len(),
    )
    .unwrap();
    assert!(matches!(
        outcome,
        RecoveryOutcome::ColdStart(ColdStartReason::AppMismatch { .. })
    ));

    let _ = std::fs::remove_file(&path);
}

fn plan_all(app: &dyn CrashApp) -> PersistPlan {
    resolve_plan_basic(app, "all").unwrap()
}

// -- spec threading ---------------------------------------------------------

#[test]
fn engine_pool_round_trips_and_runs_through_the_api() {
    let spec = ExperimentSpec {
        apps: vec!["toy".into()],
        plans: vec![PlanSpec::parse("all").unwrap()],
        tests: 3,
        engine: EngineKind::Pool,
        ..ExperimentSpec::default()
    };
    // JSON round-trip keeps the engine.
    let back = ExperimentSpec::from_json(&spec.to_json().to_pretty()).unwrap();
    assert_eq!(back, spec);
    assert_eq!(back.engine, EngineKind::Pool);
    assert_eq!(EngineKind::from_name("pool").unwrap(), EngineKind::Pool);

    // Validation: no verified mode, no sharding on the pool engine.
    let verified = ExperimentSpec { verified: true, ..spec.clone() };
    assert!(verified.validate().is_err());
    let sharded = ExperimentSpec { shards: 2, ..spec.clone() };
    assert!(sharded.validate().is_err());

    // End-to-end: the runner's pool cell matches the native cell.
    let runner = Runner::new(spec).unwrap();
    let report = runner.run().unwrap();
    assert_eq!(report.cells.len(), 1);
    let native = ExperimentSpec { engine: EngineKind::Native, ..runner.spec().clone() };
    let native_report = Runner::new(native).unwrap().run().unwrap();
    assert_eq!(report.cells[0].result.records, native_report.cells[0].result.records);
}

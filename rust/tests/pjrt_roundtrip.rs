//! End-to-end AOT bridge tests: JAX/Pallas → HLO text → PJRT compile →
//! execute from Rust, with numerics checked against the native kernels.
//!
//! Requires `make artifacts` (skipped with a message otherwise, so plain
//! `cargo test` stays green on a fresh checkout).

use easycrash::apps::{by_name, AppCore};
use easycrash::runtime::{PjrtEngine, StepEngine};
use easycrash::sim::{Env, RawEnv};

fn engine_or_skip() -> Option<PjrtEngine> {
    match PjrtEngine::from_default_dir() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e}");
            None
        }
    }
}

#[test]
fn artifacts_enumerate() {
    let Some(eng) = engine_or_skip() else { return };
    let av = eng.available();
    for name in ["cg_step", "kmeans_step", "mg_vcycle", "kmeans_inertia"] {
        assert!(av.iter().any(|a| a == name), "missing artifact {name}: {av:?}");
    }
}

#[test]
fn kmeans_step_pjrt_matches_native() {
    let Some(mut eng) = engine_or_skip() else { return };
    let km = easycrash::apps::kmeans::Kmeans::default();

    // Native iteration.
    let mut raw_native = RawEnv::new();
    let st_n = km.build(&mut raw_native).unwrap();
    km.step(&mut raw_native, &st_n, 0).unwrap();

    // PJRT iteration from identical initial state.
    let mut raw_pjrt = RawEnv::new();
    let st_p = km.build(&mut raw_pjrt).unwrap();
    km.step_fast(&mut raw_pjrt, &st_p, 0, &mut eng).unwrap();
    assert_eq!(eng.calls(), 1, "PJRT path must actually execute");

    let cn = raw_native.f32_slice(raw_native.buf_of(1).expect("centroid buf"));
    let cp = raw_pjrt.f32_slice(raw_pjrt.buf_of(1).expect("centroid buf"));
    for (i, (a, b)) in cn.iter().zip(cp).enumerate() {
        assert!(
            (a - b).abs() <= 1e-3 * a.abs().max(1.0),
            "centroid[{i}]: native {a} vs pjrt {b}"
        );
    }
}

#[test]
fn cg_step_pjrt_matches_native() {
    let Some(mut eng) = engine_or_skip() else { return };
    let cg = easycrash::apps::cg::Cg::default();

    let mut a = RawEnv::new();
    let st_a = cg.build(&mut a).unwrap();
    cg.step(&mut a, &st_a, 0).unwrap();

    let mut b = RawEnv::new();
    let st_b = cg.build(&mut b).unwrap();
    cg.step_fast(&mut b, &st_b, 0, &mut eng).unwrap();

    // Compare x (buf id 3 in CG's allocation order) on a sample.
    let xa = a.buf_of(3).unwrap();
    let xb = b.buf_of(3).unwrap();
    for i in (0..9216).step_by(733) {
        let va = a.ldf(xa, i).unwrap();
        let vb = b.ldf(xb, i).unwrap();
        assert!(
            (va - vb).abs() <= 1e-4 + 1e-3 * va.abs(),
            "x[{i}]: native {va} vs pjrt {vb}"
        );
    }
}

#[test]
fn mg_vcycle_pjrt_converges_like_native() {
    let Some(mut eng) = engine_or_skip() else { return };
    let mg = easycrash::apps::mg::Mg::default();

    // Run 6 PJRT vcycles; the residual norm trajectory must shrink at a
    // rate comparable to native (same algorithm, different relaxation
    // ordering — trajectories differ, convergence must not).
    let mut nat = RawEnv::new();
    let st_n = mg.build(&mut nat).unwrap();
    for it in 0..6 {
        mg.step(&mut nat, &st_n, it).unwrap();
    }
    let rn = mg.metric(&mut nat, &st_n).unwrap();

    let mut pj = RawEnv::new();
    let st_p = mg.build(&mut pj).unwrap();
    for it in 0..6 {
        mg.step_fast(&mut pj, &st_p, it, &mut eng).unwrap();
    }
    let rp = mg.metric(&mut pj, &st_p).unwrap();
    assert!(
        rp < rn * 3.0 && rp.is_finite(),
        "pjrt vcycle residual {rp} vs native {rn}"
    );
}

#[test]
fn pjrt_campaign_on_kmeans_matches_native_shape() {
    // kmeans' tolerance-band acceptance is engine-compatible: a full crash
    // campaign driven through PJRT must land near the native campaign.
    let Some(mut eng) = engine_or_skip() else { return };
    let app = by_name("kmeans").unwrap();
    let c = easycrash::easycrash::Campaign::new(40, 17);
    let plan = easycrash::easycrash::PersistPlan::none();
    let r_pjrt = c.run(app.as_ref(), &plan, &mut eng).unwrap();
    let mut native = easycrash::runtime::NativeEngine::new();
    let r_nat = c.run(app.as_ref(), &plan, &mut native).unwrap();
    let d = (r_pjrt.recomputability() - r_nat.recomputability()).abs();
    assert!(d <= 0.25, "pjrt {} vs native {}", r_pjrt.recomputability(), r_nat.recomputability());
    assert!(eng.calls() > 0);
}

//! Integration tests for `easycrash::rank` (ISSUE §Ranks): the R=1
//! distributed CG is record-identical to the native single-env CG, rank
//! campaigns are bit-identical for any shard count × recovery mode, and
//! assisted recovery survives crash points pinned mid-allreduce.

use easycrash::apps::cg::Cg;
use easycrash::apps::dcg::{self, Dcg};
use easycrash::easycrash::{Campaign, PersistPlan, Phase, RankCampaign, RecoveryMode};
use easycrash::runtime::NativeEngine;
use easycrash::sim::SimConfig;

/// A plan that persists the live CG vectors at iteration end — the same
/// DSL resolves on native `cg` and on every rank of `dcg` (plain names
/// project onto the `.r<k>` suffixed per-rank objects).
fn plan() -> PersistPlan {
    PersistPlan::at_iter_end(&["x", "r", "p"], dcg::NUM_REGIONS, 1)
}

fn mini_campaign(tests: usize) -> Campaign {
    let mut c = Campaign::new(tests, 0xEC);
    c.cfg = SimConfig::mini();
    c
}

/// ISSUE test (a): at `ranks == 1` the dcg app allocates cg's exact
/// object set under the same names and emits a bit-identical access
/// stream, so a campaign over it is record-identical to native CG — and
/// the rank harness itself (RankCampaign with one rank) reproduces the
/// same records again through its own windowed replay path.
#[test]
fn r1_dcg_campaign_is_record_identical_to_native_cg() {
    let plan = plan();
    let camp = mini_campaign(48);
    let native = camp
        .run(&Cg::default(), &plan, &mut NativeEngine::new())
        .expect("native cg campaign");
    let flat = camp
        .run(&Dcg::with_ranks(1), &plan, &mut NativeEngine::new())
        .expect("dcg r=1 campaign");
    assert_eq!(
        native.records, flat.records,
        "dcg at ranks=1 must crash and classify exactly like native cg"
    );
    assert_eq!(native.ops_total, flat.ops_total, "identical access streams");
    assert_eq!(native.ops_main_start, flat.ops_main_start);

    let rc = RankCampaign::new(1, 48, 0xEC);
    let ranked = rc.run(&plan).expect("rank campaign r=1");
    assert_eq!(
        ranked.result.records, flat.records,
        "the rank harness at one rank must reproduce the single-env campaign"
    );
    assert!(ranked.rank_of.iter().all(|&k| k == 0));
    assert_eq!(ranked.rank_spans.len(), 1);
    assert_eq!(
        ranked.rank_spans[0],
        flat.ops_total - flat.ops_main_start,
        "the one-rank crash-point span is the single-env main-loop span"
    );
}

/// ISSUE test (b): the same campaign split across {1, 2, 4, 8} harvest
/// shards is bit-identical — records, crashed ranks and the exchange-log
/// digest — for every recovery mode. (`replayed_ops` is bookkeeping of
/// how much work the sharding did, not part of the result contract.)
#[test]
fn rank_campaigns_are_bit_identical_across_shards_and_recovery_modes() {
    let plan = plan();
    for recovery in RecoveryMode::all() {
        let mut rc = RankCampaign::new(4, 16, 0xEC);
        rc.recovery = recovery;
        let base = rc.run(&plan).expect("unsharded rank campaign");
        assert_eq!(base.result.records.len(), 16);
        assert_eq!(base.rank_of.len(), 16);
        for shards in [2usize, 4, 8] {
            let mut sharded = rc;
            sharded.shards = shards;
            let got = sharded.run(&plan).expect("sharded rank campaign");
            assert_eq!(
                got.result.records, base.result.records,
                "{recovery}: records must be bit-identical at {shards} shards"
            );
            assert_eq!(got.rank_of, base.rank_of, "{recovery}: crashed ranks");
            assert_eq!(got.rank_spans, base.rank_spans);
            assert_eq!(
                got.msg_digest, base.msg_digest,
                "{recovery}: exchange log must not depend on sharding"
            );
        }
    }
}

/// ISSUE test (c): pin one crash point inside every rank's DotPq and
/// DotRr window of a mid-run iteration — the crash lands after the rank
/// contributed its partial dot product but before the allreduce
/// completes — and assisted recovery must classify every one without
/// panicking or erroring.
#[test]
fn assisted_recovery_survives_mid_allreduce_crashes() {
    let mut rc = RankCampaign::new(4, 0, 0xEC);
    rc.recovery = RecoveryMode::Assisted;
    let plan = plan();
    let prof = rc.profile(&plan).expect("rank profile");
    assert_eq!(prof.phase_windows.len(), 4);

    let mid_iter = prof.iters / 2;
    let mut points = Vec::new();
    let mut expect_ranks = Vec::new();
    for k in 0..prof.ranks {
        for w in &prof.phase_windows[k] {
            if w.iter == mid_iter && matches!(w.phase, Phase::DotPq | Phase::DotRr) {
                // A point fires inside a window iff lo < p <= hi.
                let p = w.lo + (w.hi - w.lo).div_ceil(2);
                let g = prof.global_of(k, p).expect("window point maps globally");
                assert_eq!(prof.locate(g), Some((k, p)), "locate inverts global_of");
                points.push(g);
                expect_ranks.push(k);
            }
        }
    }
    assert_eq!(points.len(), 8, "one DotPq + one DotRr window per rank");

    rc.tests = points.len();
    let res = rc
        .run_points(&plan, points.clone())
        .expect("assisted recovery must survive mid-allreduce crash points");
    assert_eq!(res.result.records.len(), points.len());
    let mut want: Vec<(u64, usize)> =
        points.iter().copied().zip(expect_ranks).collect();
    want.sort_unstable();
    let want_ranks: Vec<usize> = want.iter().map(|&(_, k)| k).collect();
    assert_eq!(res.rank_of, want_ranks, "each record kills the pinned rank");
    for (r, &k) in res.result.records.iter().zip(&res.rank_of) {
        assert!(
            !r.inconsistency.is_empty() && k < 4,
            "record classified with a rank-attributed inconsistency vector"
        );
    }
}

/// The pool-engine path: per-rank durable pool files, a real crashed
/// generation for the victim and recovery from what the files say
/// survived. Smoke-level — it must complete, classify every drawn point
/// and attribute crashes to the same ranks as the simulated engine
/// (the op geometry is shared; the NVM image comes from disk).
#[test]
fn pooled_rank_campaign_completes_and_matches_native_rank_attribution() {
    let mut rc = RankCampaign::new(2, 5, 0xEC);
    rc.recovery = RecoveryMode::Local;
    let plan = plan();
    let native = rc.run(&plan).expect("native rank campaign");
    let base = std::env::temp_dir().join(format!(
        "easycrash-rank-test-{}.pool",
        std::process::id()
    ));
    let pooled = rc.run_pooled(&plan, &base).expect("pooled rank campaign");
    assert_eq!(pooled.result.records.len(), native.result.records.len());
    assert_eq!(
        pooled.rank_of, native.rank_of,
        "pool engine must attribute each crash to the same rank"
    );
    for k in 0..2 {
        let p = easycrash::easycrash::rank::pool_rank_path(&base, k);
        assert!(!p.exists(), "campaign cleans up its per-rank pool files");
    }
}

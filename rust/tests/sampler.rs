//! Exploration-layer acceptance (ISSUE §Sampler): crash-equivalence
//! classes are real (any member of a class recovers like its
//! representative), the class sampler hits 100% class coverage on a
//! budget uniform sampling cannot match, and the adaptive sampler is
//! bit-reproducible for every shard count.

use easycrash::apps::by_name;
use easycrash::easycrash::{Campaign, PersistPlan, SamplerSpec, ShardedCampaign};
use easycrash::runtime::NativeEngine;

fn campaign(tests: usize, seed: u64, sampler: &str) -> Campaign {
    let mut c = Campaign::new(tests, seed);
    c.sampler = SamplerSpec::parse(sampler).expect("sampler DSL");
    c
}

fn plan_all(app: &dyn easycrash::apps::CrashApp) -> PersistPlan {
    let prof = Campaign::new(0, 1).profile(app, &PersistPlan::none()).unwrap();
    let names: Vec<String> = prof
        .selectable_candidates()
        .map(|(_, n, _)| n.clone())
        .collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    PersistPlan::at_iter_end(&refs, app.regions().len(), 1)
}

/// The equivalence-class claim itself, on toy/mg/ft: the class map is a
/// pure function of the profile (seed-independent), and `class_points`
/// under budget picks the same (widest) classes for any seed — so two
/// class campaigns with different seeds test the *same* classes through
/// *different* member crash points, and every pair of same-class members
/// must classify identically. Only the persistence-derived fields are
/// compared: `iter`, `region` and the arch-vs-NVM inconsistency all
/// legitimately vary with the exact crash op inside a class.
#[test]
fn class_members_recover_identically_to_their_representative() {
    for app_name in ["toy", "mg", "ft"] {
        let app = by_name(app_name).unwrap();
        let app = app.as_ref();
        for plan in [PersistPlan::none(), plan_all(app)] {
            let tests = 10;
            let mut eng = NativeEngine::new();
            let a = campaign(tests, 0xA, "classes").run(app, &plan, &mut eng).unwrap();
            let b = campaign(tests, 0xB, "classes").run(app, &plan, &mut eng).unwrap();
            assert_eq!(a.records.len(), b.records.len(), "{app_name}: same class set");
            assert_eq!(a.weights, b.weights, "{app_name}: class widths are seed-free");
            assert_eq!(a.coverage, b.coverage, "{app_name}: coverage is seed-free");
            for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
                assert_eq!(
                    (ra.response, ra.extra_iters),
                    (rb.response, rb.extra_iters),
                    "{app_name}: class {i} members at ops {} vs {} diverged",
                    ra.op,
                    rb.op
                );
            }
            // The claim is only exercised if the representatives actually
            // moved between the seeds.
            assert!(
                a.records.iter().zip(&b.records).any(|(ra, rb)| ra.op != rb.op),
                "{app_name}: both seeds drew identical representatives"
            );
        }
    }
}

/// Same seed, same campaign — records, weights and coverage reproduce
/// bit for bit (the memo/store layers key on this).
#[test]
fn classes_sampler_is_bit_reproducible_per_seed() {
    let app = by_name("toy").unwrap();
    let plan = PersistPlan::none();
    let mut eng = NativeEngine::new();
    let a = campaign(12, 0xEC, "classes").run(app.as_ref(), &plan, &mut eng).unwrap();
    let b = campaign(12, 0xEC, "classes").run(app.as_ref(), &plan, &mut eng).unwrap();
    assert_eq!(a.records, b.records);
    assert_eq!(a.weights, b.weights);
    assert_eq!(a.coverage, b.coverage);
    assert_eq!(a.weights.len(), a.records.len(), "one weight per record");
    assert!(a.weights.iter().all(|&w| w > 0.0));
    assert_eq!(a.cycles.to_bits(), b.cycles.to_bits());
}

/// The acceptance bar: on toy, the class sampler reports 100% class
/// coverage at a budget of exactly `classes_total` tests, while the
/// uniform draw at that same budget stays below 95% — i.e. uniform
/// needs strictly more tests to reach 95% of the persistence-distinct
/// crash states than classes needs for all of them.
#[test]
fn classes_reach_full_toy_coverage_on_a_budget_uniform_cannot() {
    let app = by_name("toy").unwrap();
    let plan = plan_all(app.as_ref());
    let mut eng = NativeEngine::new();

    // Learn the class count from a probe-sized class campaign.
    let probe = campaign(4, 0xEC, "classes").run(app.as_ref(), &plan, &mut eng).unwrap();
    let total = probe.coverage.as_ref().expect("classes emits coverage").classes_total;
    assert!(total > 4, "toy must have a non-trivial class structure, got {total}");

    let full = campaign(total, 0xEC, "classes").run(app.as_ref(), &plan, &mut eng).unwrap();
    let cov = full.coverage.as_ref().expect("coverage");
    assert_eq!(cov.classes_tested, total, "budget == classes → every class tested");
    assert_eq!(cov.covered(), 1.0);
    assert_eq!(full.records.len(), total, "one test per class, none wasted");

    let uniform = campaign(total, 0xEC, "uniform").run(app.as_ref(), &plan, &mut eng).unwrap();
    let ucov = uniform.coverage.as_ref().expect("uniform also reports coverage");
    assert_eq!(ucov.classes_total, total, "both samplers see one class map");
    assert!(
        ucov.covered() < 0.95,
        "uniform at the class budget must stay under the 95% bar, got {}",
        ucov.covered()
    );
}

/// The adaptive sampler inherits the executor's shard invariance: every
/// draw is a pure function of (seed, round, region), decided before any
/// harvesting is dispatched — so shard counts {1, 2, 4, 8} must
/// reproduce the sequential run bit for bit, coverage included.
#[test]
fn adaptive_sampler_is_bit_reproducible_across_shard_counts() {
    let app = by_name("toy").unwrap();
    let plan = PersistPlan::none();
    let mut eng = NativeEngine::new();
    let seq = campaign(24, 0x5EED, "adaptive(4)").run(app.as_ref(), &plan, &mut eng).unwrap();
    assert_eq!(seq.weights.len(), seq.records.len());
    let cov = seq.coverage.as_ref().expect("adaptive emits coverage");
    assert!(cov.classes_tested > 0);
    for shards in [1usize, 2, 4, 8] {
        let mut sc = ShardedCampaign::new(24, 0x5EED, shards);
        sc.campaign.sampler = SamplerSpec::parse("adaptive(4)").unwrap();
        let r = sc.run(app.as_ref(), &plan).unwrap();
        assert_eq!(r.records, seq.records, "shards={shards}: records diverged");
        assert_eq!(r.weights, seq.weights, "shards={shards}: weights diverged");
        assert_eq!(r.coverage, seq.coverage, "shards={shards}: coverage diverged");
        assert_eq!(r.cycles.to_bits(), seq.cycles.to_bits(), "shards={shards}");
        assert_eq!(r.stats, seq.stats, "shards={shards}");
    }
}

/// Verified mode snapshots the architectural image, which changes at
/// every op — no two crash points are equivalent, so the non-uniform
/// samplers must refuse rather than report meaningless classes.
#[test]
fn non_uniform_samplers_reject_verified_mode() {
    let app = by_name("toy").unwrap();
    let plan = PersistPlan::none();
    for sampler in ["classes", "adaptive"] {
        let mut c = campaign(8, 0xEC, sampler);
        c.verified = true;
        let mut eng = NativeEngine::new();
        let err = c.run(app.as_ref(), &plan, &mut eng).unwrap_err();
        assert!(
            err.to_string().contains("verified"),
            "{sampler}: error must name verified mode, got: {err}"
        );
    }
}

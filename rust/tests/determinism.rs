//! Determinism proof for the sharded campaign executor.
//!
//! The guarantee under test (see `easycrash::campaign` module docs):
//! `ShardedCampaign` output — records, response fractions, modeled cycles
//! — is **bit-identical** to the sequential `Campaign` under the same
//! seed, for every shard count; and shard crash-point batches never share
//! an op (the per-lane RNG split draws from disjoint op sub-ranges, and
//! batch boundaries keep duplicate draws together).

use std::collections::HashSet;

use easycrash::apps::{self, by_name, CrashApp};
use easycrash::easycrash::campaign::{draw_crash_points, partition_points};
use easycrash::easycrash::{Campaign, CampaignResult, PersistPlan, ShardedCampaign, Workflow};
use easycrash::runtime::NativeEngine;
use easycrash::sim::SimConfig;
use easycrash::util::rng::Rng;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// The two plans each app is exercised under: no persistence, and all
/// candidate objects persisted at iteration end.
fn plans_for(app: &dyn CrashApp) -> Vec<PersistPlan> {
    let prof = Campaign::new(0, 1).profile(app, &PersistPlan::none()).unwrap();
    let names: Vec<String> = prof
        .selectable_candidates()
        .map(|(_, n, _)| n.clone())
        .collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    vec![
        PersistPlan::none(),
        PersistPlan::at_iter_end(&refs, app.regions().len(), 1),
    ]
}

/// Satellite: 3 apps × 2 plans × shard counts {1, 2, 4, 8} — sharded
/// output equals sequential output field by field.
#[test]
fn sharded_equals_sequential_across_apps_plans_and_shard_counts() {
    let tests = 24;
    let seed = 0xA5;
    for app_name in ["toy", "is", "kmeans"] {
        let app = by_name(app_name).unwrap();
        for (p, plan) in plans_for(app.as_ref()).iter().enumerate() {
            let mut eng = NativeEngine::new();
            let seq = Campaign::new(tests, seed).run(app.as_ref(), plan, &mut eng).unwrap();
            assert_eq!(seq.records.len(), tests, "{app_name} plan{p}");
            for shards in SHARD_COUNTS {
                let sc = ShardedCampaign::new(tests, seed, shards);
                let r = sc.run(app.as_ref(), plan).unwrap();
                // The aggregates come from the designated full-run worker
                // (every other worker early-stops): they must still match
                // the sequential run bit for bit.
                assert_bit_identical(&r, &seq, &format!("{app_name} plan{p} shards={shards}"));
            }
        }
    }
}

fn assert_bit_identical(r: &CampaignResult, seq: &CampaignResult, label: &str) {
    assert_eq!(r.records, seq.records, "{label}: records diverged");
    assert_eq!(
        r.response_fractions(),
        seq.response_fractions(),
        "{label}: response fractions diverged"
    );
    assert_eq!(r.recomputability(), seq.recomputability(), "{label}");
    assert_eq!(r.cycles, seq.cycles, "{label}: modeled cycles diverged");
    assert_eq!(r.region_cycles, seq.region_cycles, "{label}");
    assert_eq!(r.ops_total, seq.ops_total, "{label}");
    assert_eq!(r.ops_main_start, seq.ops_main_start, "{label}");
    assert_eq!(r.persist_ops, seq.persist_ops, "{label}");
    assert_eq!(r.persist_cycles, seq.persist_cycles, "{label}");
    assert_eq!(r.stats, seq.stats, "{label}: HierStats diverged");
}

/// Satellite: the FULL registry — all 11 paper apps plus the extras
/// (toy, adi, fft, dcg), 15 apps — passes sequential-vs-sharded
/// bit-parity on a tiny campaign, so no app's access pattern (CSR
/// gathers, chain walks, Thomas sweeps, butterflies, leapfrog hydro,
/// rank-blocked CG, ...) can break the early-stop worker schedule or
/// the lane-split draw.
#[test]
fn full_fifteen_app_matrix_sharded_equals_sequential() {
    let tests = 6;
    let seed = 0x14;
    let mut covered = Vec::new();
    for app in apps::all().into_iter().chain(apps::extras()) {
        let app = app.as_ref();
        let plan = PersistPlan::none();
        let mut eng = NativeEngine::new();
        let seq = Campaign::new(tests, seed).run(app, &plan, &mut eng).unwrap();
        assert_eq!(seq.records.len(), tests, "{}", app.name());
        for shards in SHARD_COUNTS {
            let r = ShardedCampaign::new(tests, seed, shards).run(app, &plan).unwrap();
            assert_bit_identical(&r, &seq, &format!("{} shards={shards}", app.name()));
        }
        covered.push(app.name());
    }
    assert_eq!(covered.len(), 15, "the full matrix must cover 15 apps: {covered:?}");
    for name in [
        "cg", "mg", "ft", "is", "bt", "lu", "sp", "ep", "botsspar", "lulesh", "kmeans", "toy",
        "adi", "fft", "dcg",
    ] {
        assert!(covered.contains(&name), "missing {name}");
    }
}

/// Tentpole: snapshot-restore harvesting is bit-identical to scratch
/// replay across the FULL 15-app matrix, sequential and sharded alike.
/// The sequential scratch run (snapshots off) is the reference; with the
/// tape recorded at every iteration end (interval 1, the adversarial
/// maximum) the campaign must reproduce every result field bit for bit
/// for shard counts {1, 2, 4, 8} — while replaying strictly fewer
/// instrumented ops than the scratch pass.
#[test]
fn snapshot_restore_is_bit_identical_to_scratch_across_the_matrix() {
    let tests = 6;
    let seed = 0x5A;
    let snap_cfg = SimConfig::mini().with_snapshot_every(Some(1));
    let mut covered = 0;
    for app in apps::all().into_iter().chain(apps::extras()) {
        let app = app.as_ref();
        let plan = PersistPlan::none();
        let mut eng = NativeEngine::new();
        let scratch = Campaign::new(tests, seed).run(app, &plan, &mut eng).unwrap();

        let mut snap_c = Campaign::new(tests, seed);
        snap_c.cfg = snap_cfg;
        let mut eng2 = NativeEngine::new();
        let snap = snap_c.run(app, &plan, &mut eng2).unwrap();
        assert_bit_identical(&snap, &scratch, &format!("{} snap-vs-scratch", app.name()));
        assert!(
            snap.replayed_ops < scratch.replayed_ops,
            "{}: snapshot harvest must replay fewer ops ({} vs {})",
            app.name(),
            snap.replayed_ops,
            scratch.replayed_ops
        );

        for shards in SHARD_COUNTS {
            let mut sc = ShardedCampaign::new(tests, seed, shards);
            sc.campaign.cfg = snap_cfg;
            let r = sc.run(app, &plan).unwrap();
            assert_bit_identical(
                &r,
                &scratch,
                &format!("{} snap-vs-scratch shards={shards}", app.name()),
            );
        }
        covered += 1;
    }
    assert_eq!(covered, 15, "the parity matrix must cover all 15 apps");
}

/// The full 4-step workflow inherits the guarantee: sharded campaigns
/// produce the same selection, plan and final result as sequential ones.
#[test]
fn sharded_workflow_equals_sequential_workflow() {
    let app = by_name("toy").unwrap();
    let wf = Workflow {
        tests: 60,
        seed: 3,
        ..Default::default()
    };
    let mut eng = NativeEngine::new();
    let seq = wf.run(app.as_ref(), &mut eng).unwrap();
    let sh = wf
        .run_sharded(app.as_ref(), 4, &|| Box::new(NativeEngine::new()))
        .unwrap();
    assert_eq!(seq.critical, sh.critical);
    assert_eq!(seq.plan.entries, sh.plan.entries);
    assert_eq!(seq.base.records, sh.base.records);
    assert_eq!(seq.final_result.records, sh.final_result.records);
    assert_eq!(
        seq.final_result.recomputability(),
        sh.final_result.recomputability()
    );
}

/// Satellite: per-shard crash-point streams never overlap — for a
/// 1000-test campaign, no op value appears in two different shards.
#[test]
fn shard_batches_share_no_ops_in_a_1000_test_campaign() {
    let app = by_name("toy").unwrap();
    let prof = Campaign::new(1000, 7).profile(app.as_ref(), &PersistPlan::none()).unwrap();
    assert!(
        prof.ops_total - prof.ops_main_start >= 1000,
        "main loop must be wider than the test count for the structural guarantee"
    );
    let points = draw_crash_points(7, 1000, prof.ops_main_start, prof.ops_total);
    assert_eq!(points.len(), 1000);
    for shards in [2usize, 4, 8] {
        let batches = partition_points(&points, shards);
        assert_eq!(batches.iter().map(Vec::len).sum::<usize>(), 1000);
        let sets: Vec<HashSet<u64>> = batches
            .iter()
            .map(|b| b.iter().copied().collect())
            .collect();
        for i in 0..sets.len() {
            for j in i + 1..sets.len() {
                assert!(
                    sets[i].is_disjoint(&sets[j]),
                    "shards {i} and {j} share crash-point ops ({} tests each)",
                    batches[i].len()
                );
            }
        }
    }
}

/// The RNG lane split itself: 8 lanes drawing a 1000-test campaign's worth
/// of values (125 each) never collide — each lane is the master stream
/// advanced by a distinct number of 2^128-step jumps.
#[test]
fn rng_lane_streams_are_disjoint() {
    let mut seen: HashSet<u64> = HashSet::new();
    for lane in 0..8u64 {
        let mut r = Rng::for_lane(0xEC, lane);
        for i in 0..125 {
            assert!(
                seen.insert(r.next_u64()),
                "lane {lane} draw {i} duplicated an earlier lane's output"
            );
        }
    }
    assert_eq!(seen.len(), 1000);
}

/// The draw itself is shard-count-free: it depends only on
/// (seed, tests, span). Re-drawing must reproduce it exactly, and the
/// lane stratification keeps every point inside the main loop.
#[test]
fn crash_point_draw_is_reproducible_and_bounded() {
    let app = by_name("is").unwrap();
    let prof = Campaign::new(0, 2).profile(app.as_ref(), &PersistPlan::none()).unwrap();
    let (lo, hi) = (prof.ops_main_start, prof.ops_total);
    let a = draw_crash_points(2, 500, lo, hi);
    let b = draw_crash_points(2, 500, lo, hi);
    assert_eq!(a, b);
    assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted ascending");
    assert!(a.iter().all(|&p| p >= lo && p < hi), "within the main loop");
}

//! The typed experiment API: plan-DSL round-trips and rejections, spec
//! JSON round-trips, the report's golden JSON schema, and the acceptance
//! parity proof — `CampaignResult`s produced through
//! `ExperimentSpec`/`Runner` are bit-identical to the pre-redesign
//! direct `Campaign`/`ShardedCampaign` wiring for the same
//! `(app, plan, tests, seed, shards)`.

use std::sync::Arc;

use easycrash::api::{EngineKind, ExperimentSpec, Runner};
use easycrash::apps::by_name;
use easycrash::easycrash::{Campaign, CampaignResult, PersistPlan, PlanSpec, ShardedCampaign};
use easycrash::runtime::NativeEngine;
use easycrash::util::json::Json;

// -- plan DSL ---------------------------------------------------------------

#[test]
fn plan_dsl_round_trips_through_the_pretty_printer() {
    for src in [
        "none",
        "all",
        "critical",
        "u@3",
        "u@3/2",
        "u@3,r@3/2,it@0",
        "u@0/17",
    ] {
        let spec = PlanSpec::parse(src).unwrap();
        let printed = spec.to_string();
        let reparsed = PlanSpec::parse(&printed).unwrap();
        assert_eq!(spec, reparsed, "`{src}` -> `{printed}` must reparse equal");
    }
    // `/1` is the default frequency: the printer normalizes it away and
    // the normalized form still parses to the same plan.
    let verbose = PlanSpec::parse("u@3/1,r@2/1").unwrap();
    assert_eq!(verbose.to_string(), "u@3,r@2");
    assert_eq!(PlanSpec::parse("u@3,r@2").unwrap(), verbose);
    // Whitespace around entries is tolerated.
    assert_eq!(PlanSpec::parse(" u@3 , r@2 ").unwrap(), verbose);
}

#[test]
fn plan_dsl_rejects_malformed_specs() {
    for bad in [
        "",
        "   ",
        "u",          // no @
        "@3",         // empty object
        "u@",         // empty region
        "u@x",        // non-numeric region
        "u@3/",       // empty frequency
        "u@3/x",      // non-numeric frequency
        "u@3/0",      // every_x == 0
        "u@3,,r@2",   // empty entry in list
        "u@-1",       // negative region
    ] {
        assert!(PlanSpec::parse(bad).is_err(), "`{bad}` must be rejected");
    }
}

#[test]
fn plan_validation_catches_app_mismatches() {
    let objects = vec!["x".to_string(), "y".to_string()];
    // Unknown object.
    assert!(PlanSpec::parse_for("z@0", &objects, 2).is_err());
    // Region out of range (toy has 2 regions: 0 and 1).
    assert!(PlanSpec::parse_for("x@2", &objects, 2).is_err());
    // In-range entries pass.
    let ok = PlanSpec::parse_for("x@1,y@0/3", &objects, 2).unwrap();
    assert_eq!(ok.to_string(), "x@1,y@0/3");
    // Shorthands are app-valid by construction.
    PlanSpec::parse_for("all", &objects, 2).unwrap();
}

#[test]
fn all_shorthand_equals_explicit_candidate_list() {
    let app = by_name("toy").unwrap();
    let runner = Runner::new(
        ExperimentSpec::builder().app("toy").tests(0).build().unwrap(),
    )
    .unwrap();
    let via_shorthand = runner.resolve_plan(app.as_ref(), &PlanSpec::All).unwrap();
    // toy's candidates are x and y (the iterator bookmark is excluded).
    assert_eq!(runner.candidate_names(app.as_ref()), vec!["x", "y"]);
    let explicit = runner
        .resolve_plan(
            app.as_ref(),
            &PlanSpec::parse("x@1,y@1").unwrap(),
        )
        .unwrap();
    assert_eq!(via_shorthand.entries, explicit.entries);
    assert_eq!(via_shorthand.dsl(), "x@1,y@1");
}

#[test]
fn explicit_entries_may_persist_the_iterator_bookmark() {
    // Fig. 4a's first row persists `it` alone — the resolver must accept
    // it even though the `all` shorthand excludes it.
    let app = by_name("toy").unwrap();
    let runner = Runner::new(
        ExperimentSpec::builder().app("toy").tests(0).build().unwrap(),
    )
    .unwrap();
    let plan = runner
        .resolve_plan(app.as_ref(), &PlanSpec::parse("it@1").unwrap())
        .unwrap();
    assert_eq!(plan.dsl(), "it@1");
    // Unknown objects and out-of-range regions still fail at resolve.
    assert!(runner
        .resolve_plan(app.as_ref(), &PlanSpec::parse("nope@1").unwrap())
        .is_err());
    assert!(runner
        .resolve_plan(app.as_ref(), &PlanSpec::parse("x@9").unwrap())
        .is_err());
}

#[test]
fn explicit_entries_may_persist_non_candidate_objects() {
    // bt registers `forcing` with candidate=false; the old CLI accepted
    // persisting it, and the resolver must keep doing so.
    let app = by_name("bt").unwrap();
    let runner = Runner::new(
        ExperimentSpec::builder().app("bt").tests(0).build().unwrap(),
    )
    .unwrap();
    let plan = runner
        .resolve_plan(app.as_ref(), &PlanSpec::parse("forcing@0").unwrap())
        .unwrap();
    assert_eq!(plan.dsl(), "forcing@0");
}

#[test]
fn persist_plan_dsl_is_canonical() {
    assert_eq!(PersistPlan::none().dsl(), "none");
    assert_eq!(PersistPlan::at_iter_end(&["u", "r"], 4, 2).dsl(), "u@3/2,r@3/2");
    let mut clwb = PersistPlan::at_region(&["u"], 1, 1);
    clwb.clwb = true;
    assert_eq!(clwb.dsl(), "u@1+clwb");
}

// -- spec serialization -----------------------------------------------------

#[test]
fn spec_round_trips_through_json() {
    let spec = ExperimentSpec::builder()
        .apps(["toy", "is"])
        .plan(PlanSpec::None)
        .plan_str("x@1/2")
        .unwrap()
        .plan(PlanSpec::All)
        .tests(42)
        .seed(99)
        .shards(4)
        .verified(true)
        .ts(0.05)
        .tau(0.2)
        .planner_str("topk(2)+greedy")
        .unwrap()
        .snapshot_interval(Some(4096))
        .build()
        .unwrap();
    let text = spec.to_json().to_pretty();
    let back = ExperimentSpec::from_json(&text).unwrap();
    assert_eq!(back, spec);
    assert_eq!(back.cfg.snapshot_every, Some(4096));

    // Snapshots off (the default) omits the key and still round-trips.
    let off = ExperimentSpec::builder().app("toy").build().unwrap();
    let text = off.to_json().to_pretty();
    assert!(!text.contains("snapshot_interval"));
    assert_eq!(ExperimentSpec::from_json(&text).unwrap(), off);

    // `--snapshot-interval 0` disables; a JSON `0` means the same.
    let z = ExperimentSpec::from_json(r#"{"apps":["toy"],"snapshot_interval":0}"#).unwrap();
    assert_eq!(z.cfg.snapshot_every, None);
    assert!(
        ExperimentSpec::from_json(r#"{"apps":["toy"],"snapshot_interval":-3}"#).is_err(),
        "negative intervals must be rejected"
    );
}

#[test]
fn custom_geometry_round_trips_and_flag_conflict_errors() {
    use easycrash::sim::{CacheGeom, SimConfig};
    // A builder-set custom geometry serializes its dimensions and loads
    // back identically (reports stay reproducible from their spec).
    let cfg = SimConfig {
        l1: CacheGeom::new(8 * 1024, 4),
        l2: CacheGeom::new(32 * 1024, 8),
        l3: CacheGeom::new(128 * 1024, 16),
        ..SimConfig::mini()
    };
    let spec = ExperimentSpec::builder().app("toy").cfg(cfg).build().unwrap();
    let back = ExperimentSpec::from_json(&spec.to_json().to_pretty()).unwrap();
    assert_eq!(back, spec);
    // `cache` without geometry "custom" is rejected.
    assert!(ExperimentSpec::from_json(
        r#"{"apps":["toy"],"cache":{"l1":{"size":8192,"ways":4}}}"#
    )
    .is_err());
    // Conflicting verified flags are rejected rather than resolved.
    let argv: Vec<String> = ["--app", "toy", "--verified", "--no-verified"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = easycrash::util::cli::Args::parse(&argv, &["app"]).unwrap();
    assert!(ExperimentSpec::from_args(&args).is_err());
}

#[test]
fn spec_from_json_validates() {
    // Unknown app.
    assert!(ExperimentSpec::from_json(r#"{"apps":["nope"]}"#).is_err());
    // Bad plan DSL inside the file.
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"plans":["u@1/0"]}"#).is_err());
    // Shards/engine rule.
    assert!(
        ExperimentSpec::from_json(r#"{"apps":["toy"],"engine":"pjrt","shards":4}"#).is_err()
    );
    // Unknown NVM profile / geometry / planner strategy.
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"nvm":"flux"}"#).is_err());
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"geometry":"huge"}"#).is_err());
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"planner":"nope+knapsack"}"#).is_err());
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"planner":"spearman+nope"}"#).is_err());
    // Seeds beyond i64 can't round-trip through JSON integers.
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"seed":1e300}"#).is_err());
    // Integral-float fields outside f64's exact range are rejected, not
    // saturated.
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"tests":1e300}"#).is_err());
    // A nesting bomb errors instead of overflowing the stack.
    let bomb = "[".repeat(100_000);
    assert!(easycrash::util::json::Json::parse(&bomb).is_err());
    // Unknown keys are rejected, not silently defaulted (typo safety),
    // duplicates likewise, and a non-object document is rejected outright.
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"test":1000}"#).is_err());
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"tests":100,"tests":1000}"#).is_err());
    assert!(ExperimentSpec::from_json(r#"[1,2]"#).is_err());
    // Minimal valid file: defaults fill the rest.
    let spec = ExperimentSpec::from_json(r#"{"apps":["toy"]}"#).unwrap();
    assert_eq!(spec.plans, vec![PlanSpec::None]);
    assert_eq!(spec.engine, EngineKind::Native);
}

#[test]
fn flags_path_enforces_the_shards_engine_rule() {
    let argv: Vec<String> = ["--app", "toy", "--shards", "4", "--engine", "pjrt"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = easycrash::util::cli::Args::parse(&argv, &["app", "shards", "engine"]).unwrap();
    assert!(ExperimentSpec::from_args(&args).is_err());
}

/// The rank axis: `ranks`/`recovery` survive the JSON round-trip, and
/// `validate()` rejects the combinations the rank harness cannot honor
/// (ISSUE §Ranks bugfix): multi-rank campaigns are dcg-only, have no
/// single architectural image for `verified` mode, and shard internally
/// — outer `--shards` composition is rejected until proven invariant.
#[test]
fn rank_axis_round_trips_and_rejects_unsupported_combinations() {
    use easycrash::easycrash::RecoveryMode;
    let spec = ExperimentSpec::builder()
        .app("dcg")
        .tests(6)
        .ranks(4)
        .recovery(RecoveryMode::Assisted)
        .build()
        .unwrap();
    let back = ExperimentSpec::from_json(&spec.to_json().to_pretty()).unwrap();
    assert_eq!(back, spec);
    assert_eq!(back.ranks, 4);
    assert_eq!(back.recovery, RecoveryMode::Assisted);

    // Multi-rank campaigns exist only for the distributed app.
    assert!(ExperimentSpec::from_json(r#"{"apps":["toy"],"ranks":4}"#).is_err());
    assert!(ExperimentSpec::from_json(r#"{"apps":["dcg","toy"],"ranks":4}"#).is_err());
    // No single architectural image exists across ranks.
    assert!(
        ExperimentSpec::from_json(r#"{"apps":["dcg"],"ranks":4,"verified":true}"#).is_err()
    );
    // Rank campaigns shard internally; outer sharding is rejected.
    assert!(ExperimentSpec::from_json(r#"{"apps":["dcg"],"ranks":4,"shards":2}"#).is_err());
    // Unknown recovery modes and out-of-range rank counts are typed errors.
    assert!(ExperimentSpec::from_json(
        r#"{"apps":["dcg"],"ranks":4,"recovery":"sideways"}"#
    )
    .is_err());
    assert!(ExperimentSpec::from_json(r#"{"apps":["dcg"],"ranks":9}"#).is_err());
    assert!(ExperimentSpec::from_json(r#"{"apps":["dcg"],"ranks":0}"#).is_err());
    // ranks == 1 constrains nothing: any app, verified mode allowed.
    let one =
        ExperimentSpec::from_json(r#"{"apps":["toy"],"ranks":1,"verified":true}"#).unwrap();
    assert_eq!(one.ranks, 1);
    assert_eq!(one.recovery, RecoveryMode::Global);
}

// -- report golden schema ---------------------------------------------------

#[test]
fn experiment_report_json_matches_golden_schema() {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .plan(PlanSpec::None)
        .plan_str("x@1,y@1")
        .unwrap()
        .tests(12)
        .seed(5)
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let report = runner.run().unwrap();
    assert_eq!(report.cells.len(), 2, "1 app x 2 plans");

    let doc = Json::parse(&report.to_json().to_pretty()).expect("report JSON must parse");
    assert_eq!(
        doc.get("schema").and_then(Json::as_str),
        Some("easycrash.experiment/v1")
    );
    let spec_j = doc.get("spec").expect("spec embedded");
    assert_eq!(spec_j.get("schema").and_then(Json::as_str), Some("easycrash.spec/v1"));
    assert_eq!(spec_j.get("tests").and_then(Json::as_usize), Some(12));

    let cells = doc.get("cells").and_then(Json::as_arr).expect("cells array");
    assert_eq!(cells.len(), 2);
    for cell in cells {
        // The golden key set every consumer of the report relies on.
        for key in [
            "app",
            "plan",
            "plan_resolved",
            "verified",
            "tests",
            "recomputability",
            "fractions",
            "mean_extra_iters",
            "ops_total",
            "cycles",
            "persist_ops",
            "persist_cycles",
            "footprint",
            "num_regions",
            "region_recomputability",
            "candidates",
        ] {
            assert!(cell.get(key).is_some(), "cell is missing `{key}`");
        }
        assert_eq!(cell.get("app").and_then(Json::as_str), Some("toy"));
        assert_eq!(cell.get("tests").and_then(Json::as_usize), Some(12));
        let recomp = cell.get("recomputability").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&recomp));
        let fractions = cell.get("fractions").and_then(Json::as_arr).unwrap();
        assert_eq!(fractions.len(), 4);
        let sum: f64 = fractions.iter().map(|x| x.as_f64().unwrap()).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        let cands = cell.get("candidates").and_then(Json::as_arr).unwrap();
        assert!(!cands.is_empty());
        for c in cands {
            for key in ["name", "bytes", "mean_inconsistency"] {
                assert!(c.get(key).is_some(), "candidate is missing `{key}`");
            }
        }
    }
    assert_eq!(cells[0].get("plan").and_then(Json::as_str), Some("none"));
    assert_eq!(cells[1].get("plan").and_then(Json::as_str), Some("x@1,y@1"));
    assert_eq!(
        cells[1].get("plan_resolved").and_then(Json::as_str),
        Some("x@1,y@1")
    );
}

// -- parity: API wiring == direct wiring ------------------------------------

fn assert_bit_identical(a: &CampaignResult, b: &CampaignResult, label: &str) {
    assert_eq!(a.records, b.records, "{label}: records diverged");
    assert_eq!(a.candidates, b.candidates, "{label}: candidates diverged");
    assert_eq!(a.ops_total, b.ops_total, "{label}: ops_total diverged");
    assert_eq!(a.ops_main_start, b.ops_main_start, "{label}: ops_main_start diverged");
    assert_eq!(a.cycles, b.cycles, "{label}: cycles diverged");
    assert_eq!(a.region_cycles, b.region_cycles, "{label}: region cycles diverged");
    assert_eq!(a.persist_ops, b.persist_ops, "{label}: persist ops diverged");
    assert_eq!(a.persist_cycles, b.persist_cycles, "{label}: persist cycles diverged");
    assert_eq!(a.stats, b.stats, "{label}: hierarchy stats diverged");
    assert_eq!(a.footprint, b.footprint, "{label}: footprint diverged");
}

/// Acceptance: for the same `(app, plan, tests, seed, shards)`, a
/// campaign executed through the typed API is bit-identical to the
/// pre-redesign direct wiring (sequential `Campaign::run` for one
/// shard, `ShardedCampaign::run` for several).
#[test]
fn runner_campaigns_match_direct_wiring_bit_for_bit() {
    let (tests, seed) = (30, 0xEC);
    for app_name in ["toy", "is"] {
        let app = by_name(app_name).unwrap();
        for plan_dsl in ["none", "all"] {
            for shards in [1usize, 4] {
                let spec = ExperimentSpec::builder()
                    .app(app_name)
                    .plan_str(plan_dsl)
                    .unwrap()
                    .tests(tests)
                    .seed(seed)
                    .shards(shards)
                    .build()
                    .unwrap();
                let runner = Runner::new(spec).unwrap();
                let plan = runner
                    .resolve_plan(app.as_ref(), &PlanSpec::parse(plan_dsl).unwrap())
                    .unwrap();
                let via_api = runner.campaign(app.as_ref(), &plan, false).unwrap();

                // The pre-redesign wiring, assembled by hand.
                let direct = if shards == 1 {
                    let mut eng = NativeEngine::new();
                    Campaign::new(tests, seed).run(app.as_ref(), &plan, &mut eng).unwrap()
                } else {
                    ShardedCampaign::new(tests, seed, shards).run(app.as_ref(), &plan).unwrap()
                };
                assert_bit_identical(
                    &via_api,
                    &direct,
                    &format!("{app_name}/{plan_dsl}/shards{shards}"),
                );
            }
        }
    }
}

/// The runner memoizes by simulation key: asking twice returns the same
/// `Arc`, and the workflow's step-1 campaign IS the `none` cell.
#[test]
fn runner_memoizes_cells_and_shares_them_with_the_workflow() {
    let app = by_name("toy").unwrap();
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(40)
        .seed(3)
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let a = runner.campaign(app.as_ref(), &PersistPlan::none(), false).unwrap();
    let b = runner.campaign(app.as_ref(), &PersistPlan::none(), false).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "same plan key must hit the cache");
    // Verified campaigns are distinct cells.
    let v = runner.campaign(app.as_ref(), &PersistPlan::none(), true).unwrap();
    assert!(!Arc::ptr_eq(&a, &v));
    // The workflow's characterization campaign is the shared `none` cell.
    let wf = runner.workflow(app.as_ref()).unwrap();
    assert!(
        Arc::ptr_eq(&wf.base, &a),
        "workflow step 1 must be the memoized characterization cell"
    );
    // And the workflow itself is memoized (per strategy pair).
    assert!(Arc::ptr_eq(&wf, &runner.workflow(app.as_ref()).unwrap()));
}

/// `experiment` writes a parseable document whose cells agree with the
/// in-memory results (smoke for the CLI/CI path, without spawning the
/// binary).
#[test]
fn report_written_to_disk_parses_back() {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(8)
        .seed(11)
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let report = runner.run().unwrap();
    let dir = std::env::temp_dir().join("easycrash_api_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("report.json");
    report.write_json(path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let doc = Json::parse(&text).unwrap();
    let cells = doc.get("cells").and_then(Json::as_arr).unwrap();
    assert_eq!(cells.len(), 1);
    assert_eq!(
        cells[0].get("recomputability").and_then(Json::as_f64),
        Some(report.cells[0].result.recomputability())
    );
}

//! The pluggable planner API.
//!
//! * **Strategy parity** — the default `spearman+knapsack-vs-iterend`
//!   planner reproduces the pre-refactor hardwired workflow
//!   bit-identically: an oracle in this file re-implements the old
//!   `Workflow::run_cells` verbatim (Spearman selection, the knapsack
//!   plan vs. the budget-fit iteration-end plan, strictly-better-wins),
//!   and every field of the report is compared across the full 14-app
//!   matrix × shards {1, 4}.
//! * **DSL** — `PlannerSpec` round-trips through its pretty-printer and
//!   rejects malformed input.
//! * **Determinism** — the `random(seed)` floor selector is a pure
//!   function of its seed, sequential or sharded.
//! * **planner-matrix** — the 3 selector × 3 placer default sweep runs
//!   end to end and its `easycrash.planner/v1` document round-trips.

use std::sync::Arc;

use easycrash::api::{ExperimentSpec, PlannerMatrixReport, Runner};
use easycrash::apps::{self, by_name, CrashApp};
use easycrash::easycrash::plan::PlanEntry;
use easycrash::easycrash::regions::{select_regions, RegionModel, RegionSelection};
use easycrash::easycrash::selection::{critical_names, select_critical, SelectionRow};
use easycrash::easycrash::workflow::WorkflowReport;
use easycrash::easycrash::{Campaign, CampaignResult, PersistPlan, PlannerSpec, Workflow};
use easycrash::runtime::NativeEngine;
use easycrash::sim::timing::Costs;
use easycrash::sim::{SimConfig, LINE};

// ---------------------------------------------------------------------------
// The pre-refactor workflow, re-implemented verbatim as the parity oracle
// ---------------------------------------------------------------------------

struct OracleReport {
    base: Arc<CampaignResult>,
    selection: Vec<SelectionRow>,
    critical: Vec<String>,
    best: Arc<CampaignResult>,
    model: RegionModel,
    region_sel: RegionSelection,
    plan: PersistPlan,
    final_result: Arc<CampaignResult>,
}

/// The old private `Workflow::estimate_l`, copied.
fn oracle_estimate_l(
    cfg: &SimConfig,
    base: &CampaignResult,
    critical: &[&str],
    iters: u64,
    num_regions: usize,
) -> Vec<f64> {
    let costs = Costs::from_profile(&cfg.nvm);
    let blocks: usize = base
        .candidates
        .iter()
        .filter(|(_, name, _)| critical.contains(&name.as_str()))
        .map(|(_, _, bytes)| (bytes + LINE - 1) / LINE)
        .sum();
    let per_persist = blocks as f64 * costs.flush_dirty;
    let total = per_persist * iters as f64;
    let ratio = total / base.cycles.max(1.0);
    vec![ratio; num_regions]
}

/// The old `Workflow::run_cells` body (steps 1–4 hardwired to Spearman
/// selection and the knapsack-vs-iteration-end comparison), copied.
fn oracle_run_cells(
    wf: &Workflow,
    app: &dyn CrashApp,
    run_campaign: &mut dyn FnMut(&PersistPlan) -> Arc<CampaignResult>,
) -> OracleReport {
    let regions = app.regions();
    let num_regions = regions.len();

    let base = run_campaign(&PersistPlan::none());

    let selection = select_critical(&base);
    let critical: Vec<String> = critical_names(&selection)
        .into_iter()
        .map(|s| s.to_string())
        .collect();
    let crit_refs: Vec<&str> = critical.iter().map(|s| s.as_str()).collect();

    let best_plan = if crit_refs.is_empty() {
        PersistPlan::none()
    } else {
        PersistPlan::at_every_region(&crit_refs, num_regions)
    };
    let best = run_campaign(&best_plan);

    let overall_c = base.recomputability();
    let overall_cmax = best.recomputability();
    let c: Vec<f64> = (0..num_regions)
        .map(|k| base.region_recomputability(k).unwrap_or(overall_c))
        .collect();
    let cmax: Vec<f64> = (0..num_regions)
        .map(|k| {
            best.region_recomputability(k)
                .unwrap_or(overall_cmax)
                .max(c[k])
        })
        .collect();
    let a: Vec<f64> = (0..num_regions).map(|k| base.a(k)).collect();
    let l = oracle_estimate_l(&wf.cfg, &base, &crit_refs, app.nominal_iters(), num_regions);
    let model = RegionModel {
        a,
        c,
        cmax,
        l,
        is_loop: regions.iter().map(|r| r.is_loop).collect(),
    };
    let region_sel = select_regions(&model, wf.ts, wf.tau);

    let knapsack_plan = PersistPlan {
        entries: region_sel
            .choices
            .iter()
            .flat_map(|ch| {
                critical.iter().map(move |o| PlanEntry {
                    object: o.clone(),
                    region: ch.region,
                    every_x: ch.x,
                })
            })
            .collect(),
        clwb: false,
    };
    let (plan, final_result) = if critical.is_empty() {
        let res = run_campaign(&knapsack_plan);
        (knapsack_plan, res)
    } else {
        let last = num_regions - 1;
        let x_fit = (model.l[last] / wf.ts).ceil().max(1.0) as u32;
        let iter_end_plan = PersistPlan {
            entries: critical
                .iter()
                .map(|o| PlanEntry {
                    object: o.clone(),
                    region: last,
                    every_x: x_fit,
                })
                .collect(),
            clwb: false,
        };
        let a = run_campaign(&knapsack_plan);
        let b = run_campaign(&iter_end_plan);
        if b.recomputability() > a.recomputability() {
            (iter_end_plan, b)
        } else {
            (knapsack_plan, a)
        }
    };

    OracleReport {
        base,
        selection,
        critical,
        best,
        model,
        region_sel,
        plan,
        final_result,
    }
}

fn oracle_run(wf: &Workflow, app: &dyn CrashApp) -> OracleReport {
    let campaign = Campaign {
        tests: wf.tests,
        seed: wf.seed,
        cfg: wf.cfg,
        verified: false,
    };
    let mut engine = NativeEngine::new();
    oracle_run_cells(wf, app, &mut |plan| {
        Arc::new(campaign.run(app, plan, &mut engine).unwrap())
    })
}

fn assert_campaigns_bit_identical(a: &CampaignResult, b: &CampaignResult, label: &str) {
    assert_eq!(a.records, b.records, "{label}: records diverged");
    assert_eq!(a.candidates, b.candidates, "{label}: candidates diverged");
    assert_eq!(a.iter_obj, b.iter_obj, "{label}: iter_obj diverged");
    assert_eq!(a.cycles, b.cycles, "{label}: cycles diverged");
    assert_eq!(a.region_cycles, b.region_cycles, "{label}: region cycles diverged");
    assert_eq!(a.ops_total, b.ops_total, "{label}: ops_total diverged");
    assert_eq!(a.persist_ops, b.persist_ops, "{label}: persist ops diverged");
    assert_eq!(a.persist_cycles, b.persist_cycles, "{label}: persist cycles diverged");
    assert_eq!(a.stats, b.stats, "{label}: stats diverged");
}

fn assert_matches_oracle(rep: &WorkflowReport, oracle: &OracleReport, label: &str) {
    assert_campaigns_bit_identical(&rep.base, &oracle.base, &format!("{label}/base"));
    assert_eq!(rep.selection, oracle.selection, "{label}: selection rows diverged");
    assert_eq!(rep.critical, oracle.critical, "{label}: critical set diverged");
    assert_campaigns_bit_identical(&rep.best, &oracle.best, &format!("{label}/best"));
    assert_eq!(rep.model, oracle.model, "{label}: region model diverged");
    assert_eq!(rep.region_sel, oracle.region_sel, "{label}: region selection diverged");
    assert_eq!(rep.plan.entries, oracle.plan.entries, "{label}: plan diverged");
    assert_eq!(rep.plan.clwb, oracle.plan.clwb, "{label}: clwb diverged");
    assert_campaigns_bit_identical(
        &rep.final_result,
        &oracle.final_result,
        &format!("{label}/final"),
    );
}

/// Acceptance: the default planner pair reproduces the pre-refactor
/// workflow bit-identically on every app of the 14-app matrix, both
/// sequentially and with 4-way sharded campaigns.
#[test]
fn default_planner_matches_prerefactor_oracle_across_the_matrix() {
    let wf = Workflow {
        tests: 10,
        seed: 0x51,
        ..Default::default()
    };
    assert_eq!(wf.planner, PlannerSpec::default());
    let mut covered = 0;
    for app in apps::all().into_iter().chain(apps::extras()) {
        let app = app.as_ref();
        let oracle = oracle_run(&wf, app);
        let mut eng = NativeEngine::new();
        let seq = wf.run(app, &mut eng).unwrap();
        assert_matches_oracle(&seq, &oracle, &format!("{}/shards1", app.name()));
        let sharded = wf
            .run_sharded(app, 4, &|| Box::new(NativeEngine::new()))
            .unwrap();
        assert_matches_oracle(&sharded, &oracle, &format!("{}/shards4", app.name()));
        covered += 1;
    }
    assert_eq!(covered, 14, "the parity matrix must cover all 14 apps");
}

/// A deeper parity run at a campaign size where selection actually fires
/// (MG selects `u`), so the knapsack-vs-iterend comparison path is
/// exercised with a non-empty critical set.
#[test]
fn default_planner_matches_oracle_with_nonempty_selection() {
    let wf = Workflow {
        tests: 60,
        seed: 1,
        ..Default::default()
    };
    for name in ["toy", "mg"] {
        let app = by_name(name).unwrap();
        let oracle = oracle_run(&wf, app.as_ref());
        let mut eng = NativeEngine::new();
        let seq = wf.run(app.as_ref(), &mut eng).unwrap();
        assert_matches_oracle(&seq, &oracle, name);
        let sharded = wf
            .run_sharded(app.as_ref(), 4, &|| Box::new(NativeEngine::new()))
            .unwrap();
        assert_matches_oracle(&sharded, &oracle, &format!("{name}/shards4"));
    }
    // MG's critical set must be non-empty for this test to mean anything.
    let app = by_name("mg").unwrap();
    let mut eng = NativeEngine::new();
    let rep = wf.run(app.as_ref(), &mut eng).unwrap();
    assert!(!rep.critical.is_empty(), "MG must select critical objects");
}

// ---------------------------------------------------------------------------
// DSL
// ---------------------------------------------------------------------------

#[test]
fn planner_dsl_round_trips_and_rejects() {
    for src in [
        "spearman",
        "spearman(p=0.05)+knapsack",
        "topk(3)+iterend",
        "all+greedy",
        "random(7)",
        "topk(1)+knapsack-vs-iterend",
    ] {
        let spec = PlannerSpec::parse(src).unwrap();
        let printed = spec.to_string();
        assert_eq!(
            PlannerSpec::parse(&printed).unwrap(),
            spec,
            "`{src}` -> `{printed}` must reparse equal"
        );
    }
    // Canonical rendering always names the placer.
    assert_eq!(
        PlannerSpec::parse("spearman").unwrap().to_string(),
        "spearman+knapsack-vs-iterend"
    );
    for bad in [
        "",
        "nope",
        "spearman+nope",
        "topk(0)",
        "topk()",
        "spearman(p=0)",
        "spearman(q=1)",
        "random(x)",
        "all+knapsack+greedy",
    ] {
        assert!(PlannerSpec::parse(bad).is_err(), "`{bad}` must be rejected");
    }
}

#[test]
fn planner_flag_threads_into_the_spec() {
    let argv: Vec<String> = ["--app", "toy", "--planner", "topk(1)+greedy"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = easycrash::util::cli::Args::parse(&argv, &["app", "planner"]).unwrap();
    let spec = ExperimentSpec::from_args(&args).unwrap();
    assert_eq!(spec.planner, PlannerSpec::parse("topk(1)+greedy").unwrap());
    // And a bad pair is rejected at spec build time.
    let argv: Vec<String> = ["--app", "toy", "--planner", "bogus"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = easycrash::util::cli::Args::parse(&argv, &["app", "planner"]).unwrap();
    assert!(ExperimentSpec::from_args(&args).is_err());
}

// ---------------------------------------------------------------------------
// Alternative strategies
// ---------------------------------------------------------------------------

#[test]
fn topk_and_all_selectors_select_as_documented() {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(30)
        .seed(9)
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let app = by_name("toy").unwrap();
    // toy has exactly two selectable candidates (x, y); the bookmark is
    // never offered.
    let top1 = runner
        .workflow_with(app.as_ref(), PlannerSpec::parse("topk(1)+iterend").unwrap())
        .unwrap();
    assert_eq!(top1.critical.len(), 1);
    assert!(top1.selection.iter().all(|r| r.name != "it"));
    let all = runner
        .workflow_with(app.as_ref(), PlannerSpec::parse("all+iterend").unwrap())
        .unwrap();
    assert_eq!(all.critical, runner.candidate_names(app.as_ref()));
    // k beyond the candidate count selects everything.
    let topn = runner
        .workflow_with(app.as_ref(), PlannerSpec::parse("topk(99)+iterend").unwrap())
        .unwrap();
    assert_eq!(topn.critical, all.critical);
}

#[test]
fn random_selector_is_deterministic_sequential_and_sharded() {
    let planner = PlannerSpec::parse("random(123)+iterend").unwrap();
    let wf = Workflow {
        tests: 24,
        seed: 7,
        planner,
        ..Default::default()
    };
    let app = by_name("toy").unwrap();
    let mut eng = NativeEngine::new();
    let a = wf.run(app.as_ref(), &mut eng).unwrap();
    let mut eng2 = NativeEngine::new();
    let b = wf.run(app.as_ref(), &mut eng2).unwrap();
    let c = wf
        .run_sharded(app.as_ref(), 4, &|| Box::new(NativeEngine::new()))
        .unwrap();
    assert_eq!(a.selection, b.selection, "same seed, same selection");
    assert_eq!(a.critical, b.critical);
    assert_eq!(a.plan.entries, b.plan.entries);
    assert_eq!(a.selection, c.selection, "shard count must not change the draw");
    assert_eq!(a.critical, c.critical);
    assert_eq!(a.plan.entries, c.plan.entries);
    // The coin only ever picks real candidates.
    let names: Vec<String> = a.selection.iter().map(|r| r.name.clone()).collect();
    assert!(a.critical.iter().all(|n| names.contains(n)));
}

/// Satellite: an empty selection short-circuits — the step-3 and step-4
/// cells ARE the step-1 characterization `Arc`, not re-run campaigns.
/// A seeded random selector that flips no candidate produces the case
/// deterministically (toy has 2 candidates, so ~1/4 of seeds qualify).
#[test]
fn empty_selection_reuses_the_characterization_cell() {
    let app = by_name("toy").unwrap();
    let mut found = None;
    for sel_seed in 0..64u64 {
        let planner = PlannerSpec::parse(&format!("random({sel_seed})+iterend")).unwrap();
        let wf = Workflow {
            tests: 20,
            seed: 2,
            planner,
            ..Default::default()
        };
        let mut eng = NativeEngine::new();
        let rep = wf.run(app.as_ref(), &mut eng).unwrap();
        if rep.critical.is_empty() {
            found = Some(rep);
            break;
        }
    }
    let rep = found.expect("some seed in 0..64 must select no candidates");
    assert!(Arc::ptr_eq(&rep.base, &rep.final_result), "step 4 reuses step 1");
    assert!(Arc::ptr_eq(&rep.base, &rep.best), "step 3 reuses step 1");
    assert!(rep.plan.is_empty());
}

// ---------------------------------------------------------------------------
// planner-matrix report
// ---------------------------------------------------------------------------

#[test]
fn planner_matrix_runs_the_default_grid_and_round_trips() {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(20)
        .seed(5)
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let pairs = PlannerSpec::default_matrix();
    assert_eq!(pairs.len(), 9, "3 selectors x 3 placers");
    let report = runner.planner_matrix(&pairs).unwrap();
    assert_eq!(report.cells.len(), 9);
    for (cell, pair) in report.cells.iter().zip(&pairs) {
        assert_eq!(cell.app, "toy");
        assert_eq!(cell.planner, *pair, "cells stay in matrix order");
        assert!((0.0..=1.0).contains(&cell.summary.base));
        assert!((0.0..=1.0).contains(&cell.summary.final_));
    }

    // The document carries the schema tag and round-trips exactly.
    let text = report.to_json().to_pretty();
    assert!(text.contains("easycrash.planner/v1"));
    let back = PlannerMatrixReport::from_json(&text).unwrap();
    assert_eq!(back, report);

    // Rejections: a wrong schema and a malformed cell.
    assert!(PlannerMatrixReport::from_json(r#"{"schema":"easycrash.planner/v0"}"#).is_err());
    assert!(PlannerMatrixReport::from_json(r#"{"schema":"easycrash.planner/v1"}"#).is_err());
}

/// Strategy pairs that agree on an intermediate plan share its campaign:
/// `spearman+knapsack` and `spearman+iterend` both start from the same
/// characterization cell.
#[test]
fn matrix_pairs_share_memoized_campaigns() {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(20)
        .seed(5)
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let app = by_name("toy").unwrap();
    let a = runner
        .workflow_with(app.as_ref(), PlannerSpec::parse("spearman+knapsack").unwrap())
        .unwrap();
    let b = runner
        .workflow_with(app.as_ref(), PlannerSpec::parse("spearman+iterend").unwrap())
        .unwrap();
    assert!(Arc::ptr_eq(&a.base, &b.base), "step-1 cells are shared");
    // Distinct pairs are distinct workflow memo entries.
    assert!(!std::ptr::eq(a.as_ref(), b.as_ref()));
}

/// The sharded execution path used by `--shards` reports: a planner
/// sweep through a sharded runner equals the sequential one (the
/// campaigns inherit the determinism guarantee).
#[test]
fn planner_matrix_is_shard_invariant() {
    let pairs = [
        PlannerSpec::parse("spearman+knapsack").unwrap(),
        PlannerSpec::parse("topk(1)+iterend").unwrap(),
    ];
    let run = |shards: usize| {
        let spec = ExperimentSpec::builder()
            .app("toy")
            .tests(24)
            .seed(11)
            .shards(shards)
            .build()
            .unwrap();
        Runner::new(spec).unwrap().planner_matrix(&pairs).unwrap()
    };
    let seq = run(1);
    let sharded = run(4);
    // The embedded specs differ in `shards` by construction, so compare
    // the cells, not the whole reports.
    assert_eq!(seq.cells, sharded.cells, "planner cells must be shard-invariant");
}

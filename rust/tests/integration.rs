//! Cross-module integration tests: campaigns over real benchmarks, the
//! full workflow, the persistence-improves invariant, and determinism.

use easycrash::apps::{self, Response};
use easycrash::easycrash::{Campaign, PersistPlan, Workflow};
use easycrash::runtime::NativeEngine;

const TESTS: usize = 40; // small but meaningful; campaigns are deterministic

fn run(app: &str, plan: &PersistPlan, seed: u64) -> easycrash::easycrash::CampaignResult {
    let a = apps::by_name(app).unwrap();
    let mut eng = NativeEngine::new();
    Campaign::new(TESTS, seed).run(a.as_ref(), plan, &mut eng).unwrap()
}

#[test]
fn every_app_survives_a_campaign() {
    for app in apps::all() {
        let mut eng = NativeEngine::new();
        let r = Campaign::new(10, 3).run(app.as_ref(), &PersistPlan::none(), &mut eng).unwrap();
        assert_eq!(r.records.len(), 10, "{}", app.name());
        assert!(r.ops_total > 0);
        assert!(r.cycles > 0.0);
    }
}

#[test]
fn persistence_never_hurts_materially() {
    // For each app: persisting all candidates at iteration end must not
    // reduce recomputability beyond noise.
    for name in ["cg", "mg", "is", "kmeans", "botsspar"] {
        let base = run(name, &PersistPlan::none(), 11);
        let app = apps::by_name(name).unwrap();
        let names: Vec<String> = base
            .candidates
            .iter()
            .map(|(_, n, _)| n.clone())
            .filter(|n| n != "it")
            .collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let plan = PersistPlan::at_iter_end(&refs, app.regions().len(), 1);
        let with = run(name, &plan, 11);
        assert!(
            with.recomputability() + 0.15 >= base.recomputability(),
            "{name}: {} -> {}",
            base.recomputability(),
            with.recomputability()
        );
    }
}

#[test]
fn ep_fails_everything_without_persistence() {
    let r = run("ep", &PersistPlan::none(), 5);
    assert_eq!(r.recomputability(), 0.0, "EP's exact verification");
    assert!(r
        .records
        .iter()
        .all(|t| t.response == Response::S4 || t.response == Response::S3));
}

#[test]
fn is_interrupts_sometimes() {
    // The paper's IS segfault class: chain corruption must surface as S3
    // for a visible fraction of crashes.
    let r = run("is", &PersistPlan::none(), 13);
    let s3 = r
        .records
        .iter()
        .filter(|t| t.response == Response::S3)
        .count();
    assert!(s3 > 0, "expected interruptions, got fractions {:?}", r.response_fractions());
}

#[test]
fn campaigns_are_deterministic() {
    let a = run("mg", &PersistPlan::none(), 21);
    let b = run("mg", &PersistPlan::none(), 21);
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.op, y.op);
        assert_eq!(x.response, y.response);
        assert_eq!(x.inconsistency, y.inconsistency);
    }
    // Different seed -> different crash points.
    let c = run("mg", &PersistPlan::none(), 22);
    assert_ne!(
        a.records.iter().map(|t| t.op).collect::<Vec<_>>(),
        c.records.iter().map(|t| t.op).collect::<Vec<_>>()
    );
}

#[test]
fn workflow_full_pipeline_on_mg() {
    let app = apps::by_name("mg").unwrap();
    let mut eng = NativeEngine::new();
    let wf = Workflow {
        tests: 60,
        seed: 1,
        ..Default::default()
    };
    let rep = wf.run(app.as_ref(), &mut eng).unwrap();
    // The paper's MG findings: u is critical, r is not (recomputed each
    // iteration from u).
    let u = rep.selection.iter().find(|r| r.name == "u").unwrap();
    assert!(u.selected, "u must be selected: Rs={} p={}", u.rs, u.p);
    let r = rep.selection.iter().find(|r| r.name == "r").unwrap();
    assert!(!r.selected, "r must not be selected: Rs={} p={}", r.rs, r.p);
    // EasyCrash must improve on the baseline.
    assert!(
        rep.final_result.recomputability() >= rep.base.recomputability(),
        "{} -> {}",
        rep.base.recomputability(),
        rep.final_result.recomputability()
    );
    // Overhead bound honored by the model.
    assert!(rep.region_sel.predicted_overhead <= wf.ts + 1e-9);
}

#[test]
fn verified_mode_is_at_least_as_good_for_ft() {
    // §6 result verification: forcing cache/NVM consistency at the crash
    // point shows stronger recomputability. (This holds for apps whose
    // iteration re-execution is idempotent from a consistent mid-iteration
    // state, like FT with its level guard; apps with non-idempotent
    // updates — e.g. leapfrog hydro — can regress under forced
    // mid-iteration consistency, a fidelity limit noted in DESIGN.md.)
    let app = apps::by_name("ft").unwrap();
    let mut eng = NativeEngine::new();
    let mut c = Campaign::new(TESTS, 31);
    let normal = c.run(app.as_ref(), &PersistPlan::none(), &mut eng).unwrap();
    c.verified = true;
    let verified = c.run(app.as_ref(), &PersistPlan::none(), &mut eng).unwrap();
    assert!(
        verified.recomputability() + 0.10 >= normal.recomputability(),
        "verified {} vs normal {}",
        verified.recomputability(),
        normal.recomputability()
    );
}

#[test]
fn region_attribution_covers_main_loop() {
    let r = run("bt", &PersistPlan::none(), 41);
    // Every crash lands in a valid region (or the inter-region bucket).
    let nr = apps::by_name("bt").unwrap().regions().len();
    assert!(r.records.iter().all(|t| t.region <= nr));
    // a_k ratios sum to ~1.
    let total: f64 = (0..=nr).map(|k| r.a(k)).sum();
    assert!((total - 1.0).abs() < 1e-9, "{total}");
}

#[test]
fn nvm_write_accounting_monotone_under_flushing() {
    // Flushing can only add NVM writes vs the baseline run.
    let base = run("sp", &PersistPlan::none(), 51);
    let app = apps::by_name("sp").unwrap();
    let plan = PersistPlan::at_iter_end(&["u"], app.regions().len(), 1);
    let with = run("sp", &plan, 51);
    assert!(with.stats.nvm_writes() >= base.stats.nvm_writes());
    assert!(with.persist_ops > 0);
    assert!(with.persist_cycles > 0.0);
}

//! Real-process kill harness: spawn this crate's binary against a pool
//! file, SIGKILL it at the sampled op, restart and classify — and the
//! crash-during-recovery (double kill) / watchdog-timeout paths.
//!
//! These tests exec `CARGO_BIN_EXE_easycrash`, so they only run through
//! `cargo test` (which builds the binary first).

use std::path::{Path, PathBuf};
use std::time::Duration;

use easycrash::apps::{self, CrashApp};
use easycrash::easycrash::killcampaign::resolve_plan_basic;
use easycrash::easycrash::KillCampaign;
use easycrash::runtime::NativeEngine;
use easycrash::sim::{PoolEnv, RecoveryOutcome, Signal, SimConfig, SimEnv};

fn exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_easycrash"))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("easycrash-killtest-{}-{name}.pool", std::process::id()))
}

/// Halt a run mid-flight in-process and abandon it, leaving the same
/// dirty pool file a SIGKILLed child leaves. Returns its generation.
fn dirty_pool(path: &Path, app: &dyn CrashApp, halt: u64) -> u64 {
    let plan = resolve_plan_basic(app, "all").unwrap();
    let probe = app.probe_layout().unwrap();
    let num_regions = app.regions().len();
    let hooks = plan.resolve_for(&probe.reg, num_regions, probe.iter_obj).unwrap();
    let mut pool =
        PoolEnv::create(path, app.name(), &probe.reg, probe.iter_obj, num_regions).unwrap();
    pool.begin_run().unwrap();
    let generation = pool.generation();
    let mut env = SimEnv::new(&SimConfig::mini(), num_regions);
    env.set_hooks(hooks);
    pool.attach(&mut env).unwrap();
    env.halt_at = Some(halt);
    assert!(matches!(app.run_sim(&mut env), Err(Signal::Crash)));
    generation
}

/// The acceptance smoke: a spawn→SIGKILL→restart→verify campaign on the
/// toy app completes deterministically and agrees record-by-record with
/// the in-process pool campaign over the same seed.
#[test]
fn sigkill_campaign_matches_the_in_process_pool_campaign() {
    let app = apps::by_name("toy").unwrap();
    let app = app.as_ref();
    let plan = resolve_plan_basic(app, "all").unwrap();
    let kc = KillCampaign { tests: 3, seed: 0x417, ..KillCampaign::default() };
    let killed = kc.run_killed(&exe(), app, "all", &tmp("killed")).unwrap();
    let mut engine = NativeEngine::new();
    let in_process = kc.run_in_process(app, &plan, &tmp("inproc"), &mut engine).unwrap();
    assert_eq!(killed.records.len(), 3);
    assert_eq!(killed.records, in_process.records);
    // Every kill recovered into a classified response.
    assert!(killed.records.iter().all(|r| r.op > 0));
}

/// Crash during recovery: a recovery child is SIGKILLed mid-restart (the
/// watchdog fires during its stall); the pool must stay resumable — the
/// offline phase never mutates a resumable pool — and a second recovery
/// must succeed.
#[test]
fn double_kill_leaves_the_pool_recoverable() {
    let app = apps::by_name("toy").unwrap();
    let app = app.as_ref();
    let path = tmp("doublekill");
    let generation = dirty_pool(&path, app, 20_000);

    // First recovery attempt: stalled child, short watchdog — the parent
    // SIGKILLs it mid-recovery and reports the timeout.
    let stalled = KillCampaign {
        timeout: Duration::from_millis(500),
        stall_recovery_ms: 5_000,
        ..KillCampaign::default()
    };
    let err = stalled.spawn_recovery(&exe(), "toy", &path, Some(generation));
    assert!(err.is_err(), "the watchdog must kill the stalled recovery");

    // Second recovery: the pool survived the killed recovery attempt.
    let kc = KillCampaign::default();
    let report = kc.spawn_recovery(&exe(), "toy", &path, Some(generation)).unwrap();
    assert!(report.resumed, "second recovery must resume: {}", report.reason);
    assert_eq!(report.generation, generation);
    assert!(report.response.is_some());

    // And the in-process two-phase restart agrees.
    let probe = app.probe_layout().unwrap();
    let (_, outcome) = PoolEnv::open_expecting(
        &path,
        "toy",
        &probe.reg,
        probe.iter_obj,
        app.regions().len(),
        Some(generation),
    )
    .unwrap();
    assert!(matches!(outcome, RecoveryOutcome::Resumed { generation: g, .. } if g == generation));
    let _ = std::fs::remove_file(&path);
}

/// The retry/backoff loop: with a stalled recovery child and a retry
/// budget of 0, the harness reports the watchdog error; with the stall
/// removed the same pool recovers on the first attempt.
#[test]
fn recovery_watchdog_times_out_and_reports() {
    let app = apps::by_name("toy").unwrap();
    let app = app.as_ref();
    let path = tmp("watchdog");
    let generation = dirty_pool(&path, app, 20_000);
    let stalled = KillCampaign {
        timeout: Duration::from_millis(400),
        retries: 0,
        stall_recovery_ms: 5_000,
        ..KillCampaign::default()
    };
    let err = stalled.spawn_recovery(&exe(), "toy", &path, Some(generation)).unwrap_err();
    assert!(
        err.to_string().contains("watchdog"),
        "error must name the watchdog: {err}"
    );
    let ok = KillCampaign::default();
    let report = ok.spawn_recovery(&exe(), "toy", &path, Some(generation)).unwrap();
    assert!(report.resumed);
    let _ = std::fs::remove_file(&path);
}

//! Fast-path parity proof (ISSUE 2 / DESIGN.md §Perf).
//!
//! Two invariants are under test, both bit-for-bit:
//!
//! 1. **Scalar vs bulk API**: a `*_slice` call on `SimEnv` is exactly its
//!    per-element scalar expansion — same op indices, same crash-point
//!    firing (including points landing mid-slice), same `HierStats`, same
//!    modeled cycles, same architectural and NVM images.
//! 2. **Early-stop shards vs sequential**: `ShardedCampaign` (whose
//!    non-final workers halt right after their last crash point, and
//!    whose aggregates come from the single designated full-run worker)
//!    reproduces the sequential `Campaign` field by field, across apps,
//!    plans and shard counts.

use easycrash::apps::{by_name, CrashApp};
use easycrash::easycrash::{Campaign, PersistPlan, ShardedCampaign};
use easycrash::runtime::NativeEngine;
use easycrash::sim::{
    Buf, CrashInfo, CrashObserver, Env, FlushEntry, FlushHooks, ObjSpec, SimConfig, SimEnv,
};

/// Observer that records everything comparable at each crash point.
struct Probe {
    hits: Vec<(u64, u64, usize, f64)>,
}

impl CrashObserver for Probe {
    fn on_crash(&mut self, env: &mut SimEnv<'_>, info: CrashInfo) {
        self.hits
            .push((info.op, info.iter, info.region, env.inconsistent_rate(0)));
    }
}

fn build_env<'a>(cfg: &SimConfig) -> (SimEnv<'a>, Buf, Buf, Buf) {
    let mut env = SimEnv::new(cfg, 1);
    let x = env.alloc(ObjSpec::f64("x", 256, true));
    let y = env.alloc(ObjSpec::f32("y", 256, true));
    let z = env.alloc(ObjSpec::i64("z", 256, true));
    // A live flush hook so the memoized-line / flush interplay is on the
    // tested path too.
    let mut hooks = FlushHooks::none(1);
    hooks.at_region_end[0].push(FlushEntry::for_object(env.reg.get(x.id), 1));
    env.set_hooks(hooks);
    (env, x, y, z)
}

/// The element sequence both drivers execute: unaligned bases, runs that
/// cross many cache lines, all three element types, loads and stores.
const ITERS: u64 = 3;

fn scalar_driver(env: &mut SimEnv, x: Buf, y: Buf, z: Buf) {
    scalar_driver_from(env, x, y, z, 0);
}

/// The same element sequence resumed at iteration `start` — the replay
/// half of the snapshot/restore parity proof (snapshots are captured at
/// iteration boundaries, so `start` is the snapshot's `iter()`).
fn scalar_driver_from(env: &mut SimEnv, x: Buf, y: Buf, z: Buf, start: u64) {
    for it in start..ITERS {
        env.region(0).unwrap();
        for i in 0..200 {
            env.st(x, 3 + i, i as f64 * 1.5 - it as f64).unwrap();
        }
        let mut acc = 0.0f64;
        for i in 0..200 {
            acc += env.ld(x, 3 + i).unwrap();
        }
        env.st(x, 0, acc).unwrap();
        for i in 0..100 {
            env.stf(y, 5 + i, i as f32 + it as f32).unwrap();
        }
        let mut f = 0.0f32;
        for i in 0..100 {
            f += env.ldf(y, 5 + i).unwrap();
        }
        env.stf(y, 0, f).unwrap();
        for i in 0..50 {
            env.sti(z, 7 + i, i as i64 * 3).unwrap();
        }
        let mut s = 0i64;
        for i in 0..50 {
            s += env.ldi(z, 7 + i).unwrap();
        }
        env.sti(z, 0, s).unwrap();
        env.iter_end(it).unwrap();
    }
}

fn bulk_driver(env: &mut SimEnv, x: Buf, y: Buf, z: Buf) {
    for it in 0..ITERS {
        env.region(0).unwrap();
        let vals: Vec<f64> = (0..200).map(|i| i as f64 * 1.5 - it as f64).collect();
        env.st_slice(x, 3, &vals).unwrap();
        let mut out = vec![0.0f64; 200];
        env.ld_slice(x, 3, &mut out).unwrap();
        let mut acc = 0.0f64;
        for &v in &out {
            acc += v;
        }
        env.st(x, 0, acc).unwrap();
        let valsf: Vec<f32> = (0..100).map(|i| i as f32 + it as f32).collect();
        env.st_slice_f32(y, 5, &valsf).unwrap();
        let mut outf = vec![0.0f32; 100];
        env.ld_slice_f32(y, 5, &mut outf).unwrap();
        let mut f = 0.0f32;
        for &v in &outf {
            f += v;
        }
        env.stf(y, 0, f).unwrap();
        let valsi: Vec<i64> = (0..50).map(|i| i * 3).collect();
        env.st_slice_i64(z, 7, &valsi).unwrap();
        let mut outi = vec![0i64; 50];
        env.ld_slice_i64(z, 7, &mut outi).unwrap();
        let mut s = 0i64;
        for &v in &outi {
            s += v;
        }
        env.sti(z, 0, s).unwrap();
        env.iter_end(it).unwrap();
    }
}

/// Crash points chosen to land mid-run inside bulk slices (including a
/// duplicate, which must fire twice at the same op).
fn crash_points() -> Vec<u64> {
    vec![5, 210, 250, 404, 405, 405, 700, 710, 1300, 2000]
}

#[test]
fn bulk_api_is_bit_identical_to_scalar_expansion() {
    let cfg = SimConfig::mini();
    let mut pa = Probe { hits: Vec::new() };
    let mut pb = Probe { hits: Vec::new() };

    let (ops_a, stats_a, cycles_a, by_region_a, arch_a, nvm_a) = {
        let (mut env, x, y, z) = build_env(&cfg);
        env.set_crash_points(crash_points(), &mut pa);
        scalar_driver(&mut env, x, y, z);
        env.sync_clock();
        (
            env.ops(),
            env.hier.stats,
            env.clock.cycles,
            env.clock.by_region.clone(),
            env.mem.arch.clone(),
            env.mem.nvm.clone(),
        )
    };
    let (ops_b, stats_b, cycles_b, by_region_b, arch_b, nvm_b) = {
        let (mut env, x, y, z) = build_env(&cfg);
        env.set_crash_points(crash_points(), &mut pb);
        bulk_driver(&mut env, x, y, z);
        env.sync_clock();
        (
            env.ops(),
            env.hier.stats,
            env.clock.cycles,
            env.clock.by_region.clone(),
            env.mem.arch.clone(),
            env.mem.nvm.clone(),
        )
    };

    assert_eq!(ops_a, ops_b, "op counts");
    assert_eq!(stats_a, stats_b, "HierStats");
    assert_eq!(cycles_a.to_bits(), cycles_b.to_bits(), "modeled cycles");
    let bits = |v: &[f64]| v.iter().map(|c| c.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&by_region_a), bits(&by_region_b), "per-region cycles");
    assert_eq!(arch_a, arch_b, "architectural image");
    assert_eq!(nvm_a, nvm_b, "persisted image");
    assert_eq!(pa.hits.len(), crash_points().len(), "every point fired");
    for (a, b) in pa.hits.iter().zip(&pb.hits) {
        assert_eq!(a.0, b.0, "crash op");
        assert_eq!(a.1, b.1, "crash iter");
        assert_eq!(a.2, b.2, "crash region");
        assert_eq!(a.3.to_bits(), b.3.to_bits(), "inconsistency at crash");
    }
}

/// Tentpole: a snapshot taken at an iteration boundary, restored into a
/// fresh allocation-identical env and replayed to completion, reproduces
/// the uninterrupted run's architectural image, persisted NVM image,
/// `HierStats`, op count and modeled cycles bit for bit — for every
/// snapshot on the tape.
#[test]
fn snapshot_restore_replay_is_bit_identical_at_image_level() {
    let cfg = SimConfig::mini();
    let (tape, ops_a, stats_a, cycles_a, arch_a, nvm_a) = {
        let (mut env, x, y, z) = build_env(&cfg);
        env.record_snapshots(1);
        scalar_driver(&mut env, x, y, z);
        env.sync_clock();
        (
            env.take_tape(),
            env.ops(),
            env.hier.stats,
            env.clock.cycles,
            env.mem.arch.clone(),
            env.mem.nvm.clone(),
        )
    };
    assert!(!tape.is_empty(), "interval 1 must record at iteration ends");
    for i in 0..tape.len() {
        let snap = tape.get(i);
        let (mut env, x, y, z) = build_env(&cfg);
        env.restore(snap);
        assert_eq!(env.ops(), snap.ops(), "snapshot {i}: restored op index");
        scalar_driver_from(&mut env, x, y, z, snap.iter());
        env.sync_clock();
        assert_eq!(env.ops(), ops_a, "snapshot {i}: op count");
        assert_eq!(env.hier.stats, stats_a, "snapshot {i}: HierStats");
        assert_eq!(
            env.clock.cycles.to_bits(),
            cycles_a.to_bits(),
            "snapshot {i}: modeled cycles"
        );
        assert_eq!(env.mem.arch, arch_a, "snapshot {i}: architectural image");
        assert_eq!(env.mem.nvm, nvm_a, "snapshot {i}: persisted image");
    }
}

/// The two plans each app is exercised under: no persistence, and all
/// candidate objects persisted at iteration end.
fn plans_for(app: &dyn CrashApp) -> Vec<PersistPlan> {
    let prof = Campaign::new(0, 1).profile(app, &PersistPlan::none()).unwrap();
    let names: Vec<String> = prof
        .candidates
        .iter()
        .map(|(_, n, _)| n.clone())
        .filter(|n| n != "it")
        .collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    vec![
        PersistPlan::none(),
        PersistPlan::at_iter_end(&refs, app.regions().len(), 1),
    ]
}

/// Satellite: 3 apps × 2 plans × early-stop shards {1,2,4,8} — records,
/// `HierStats` and modeled cycles bit-identical to the sequential
/// campaign. (determinism.rs covers toy/is/kmeans; this covers the other
/// converted flagships, so every bulk-API kernel is under a campaign
/// parity test somewhere.)
#[test]
fn early_stop_shards_match_sequential_bit_for_bit() {
    let tests = 24;
    let seed = 0x51;
    for app_name in ["toy", "ft", "lulesh"] {
        let app = by_name(app_name).unwrap();
        for (p, plan) in plans_for(app.as_ref()).iter().enumerate() {
            let mut eng = NativeEngine::new();
            let seq = Campaign::new(tests, seed).run(app.as_ref(), plan, &mut eng).unwrap();
            assert_eq!(seq.records.len(), tests, "{app_name} plan{p}");
            for shards in [1usize, 2, 4, 8] {
                let r = ShardedCampaign::new(tests, seed, shards).run(app.as_ref(), plan).unwrap();
                let label = format!("{app_name} plan{p} shards={shards}");
                assert_eq!(r.records, seq.records, "{label}: records");
                assert_eq!(r.stats, seq.stats, "{label}: HierStats");
                assert_eq!(
                    r.cycles.to_bits(),
                    seq.cycles.to_bits(),
                    "{label}: modeled cycles"
                );
                assert_eq!(r.region_cycles, seq.region_cycles, "{label}: region cycles");
                assert_eq!(r.persist_ops, seq.persist_ops, "{label}: persist ops");
                assert_eq!(r.persist_cycles, seq.persist_cycles, "{label}");
                assert_eq!(r.ops_total, seq.ops_total, "{label}: ops");
                assert_eq!(r.ops_main_start, seq.ops_main_start, "{label}");
                assert_eq!(r.footprint, seq.footprint, "{label}");
            }
        }
    }
}

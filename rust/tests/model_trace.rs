//! Monte Carlo failure-timeline simulator vs the §7 closed-form model.
//!
//! The statistical acceptance gate of the trace subsystem: for each of
//! the paper's T_chk scenarios at MTBF = 12 h, the sharded Monte Carlo
//! efficiency (10⁴ trials, Exponential failures, recomputability
//! *measured* by a crash campaign) must match
//! `model::efficiency::evaluate` within 2% absolute; trace results must
//! be bit-identical across shard counts {1, 2, 4, 8}; and the analytic
//! degenerate cases must hold exactly (R = 0 ≡ CheckpointOnly,
//! MTBF → ∞ ⇒ efficiency → 1/(1+t_s)). See DESIGN.md §Model for the
//! tolerance methodology.

use easycrash::api::{ExperimentSpec, Runner, TraceSpec};
use easycrash::easycrash::PlanSpec;
use easycrash::model::efficiency::{evaluate, EfficiencyInput};
// The same scenario constant the `efficiency` pipeline iterates, so this
// gate can never drift from what the subcommand actually runs.
use easycrash::model::sweep::T_CHK_SCENARIOS;
use easycrash::model::trace::{FailureDist, RecoveryPolicy, TraceInput, TraceSim};
use easycrash::util::json::Json;

const MTBF_12H: f64 = 12.0 * 3600.0;

/// Measured recomputability: a small `toy` campaign under the `all`
/// plan, through the same Runner wiring the `efficiency` subcommand
/// uses.
fn measured_r() -> f64 {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(60)
        .seed(0xEC)
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let app = easycrash::apps::by_name("toy").unwrap();
    let plan = runner.resolve_plan(app.as_ref(), &PlanSpec::All).unwrap();
    runner.campaign(app.as_ref(), &plan, false).unwrap().recomputability()
}

/// Acceptance: MC means converge to Eq. 6 (CheckpointOnly) and Eq. 8
/// (EasyCrash + checkpoint) within 2% absolute for every T_chk scenario,
/// at the campaign-measured R.
#[test]
fn monte_carlo_matches_the_analytic_model_within_2pct() {
    let r = measured_r();
    assert!(
        r > 0.0 && r <= 1.0,
        "toy's all-candidates campaign must recompute sometimes, got R={r}"
    );
    let sim = TraceSim {
        trials: 10_000,
        seed: 7,
        shards: 4,
    };
    for t_chk in T_CHK_SCENARIOS {
        let model = EfficiencyInput::paper(MTBF_12H, t_chk, r, 0.015, 0.9).unwrap();
        let analytic = evaluate(&model).unwrap();
        let scenario = |policy| TraceInput {
            model,
            policy,
            dist: FailureDist::Exponential,
            work: 60.0 * 86_400.0,
            interval: None,
        };
        let base = sim.run(&scenario(RecoveryPolicy::CheckpointOnly)).unwrap();
        assert!(
            (base.mean_efficiency - analytic.base).abs() < 0.02,
            "T_chk={t_chk}: MC base {} vs Eq.6 {} (SE {})",
            base.mean_efficiency,
            analytic.base,
            base.std_error()
        );
        let ec = sim
            .run(&scenario(RecoveryPolicy::EasyCrashPlusCheckpoint))
            .unwrap();
        assert!(
            (ec.mean_efficiency - analytic.easycrash).abs() < 0.02,
            "T_chk={t_chk}: MC easycrash {} vs Eq.8 {} (SE {})",
            ec.mean_efficiency,
            analytic.easycrash,
            ec.std_error()
        );
        // The sampling error itself must be far inside the tolerance,
        // so the assertion tests the model, not the noise.
        assert!(base.std_error() < 0.004, "{}", base.std_error());
        assert!(ec.std_error() < 0.004, "{}", ec.std_error());
    }
}

/// The lane-split invariant: per-trial outcomes — and therefore every
/// aggregate — are bit-identical for shard counts {1, 2, 4, 8}, for
/// every policy and both interarrival distributions.
#[test]
fn trace_results_are_bit_identical_across_shard_counts() {
    let model = EfficiencyInput::paper(MTBF_12H, 320.0, 0.8, 0.015, 0.9).unwrap();
    for policy in [
        RecoveryPolicy::CheckpointOnly,
        RecoveryPolicy::EasyCrashPlusCheckpoint,
        RecoveryPolicy::NvmRestartOnly,
    ] {
        for dist in [FailureDist::Exponential, FailureDist::Weibull { shape: 0.7 }] {
            let inp = TraceInput {
                model,
                policy,
                dist,
                work: 10.0 * 86_400.0,
                interval: None,
            };
            let seq = TraceSim {
                trials: 2_000,
                seed: 0xEC,
                shards: 1,
            }
            .run(&inp)
            .unwrap();
            assert_eq!(seq.outcomes.len(), 2_000);
            for shards in [2usize, 4, 8] {
                let sh = TraceSim {
                    trials: 2_000,
                    seed: 0xEC,
                    shards,
                }
                .run(&inp)
                .unwrap();
                assert_eq!(sh, seq, "{policy:?}/{dist:?} shards={shards} diverged");
            }
        }
    }
}

/// Degenerate case 1: with R = 0 and t_s = 0, EasyCrash+checkpoint and
/// plain CheckpointOnly consume identical RNG streams (the restart coin
/// is drawn by both and can never land below 0) and use the same Young
/// interval — the timelines must be bit-identical, not just close.
#[test]
fn r_zero_easycrash_reduces_to_checkpoint_only() {
    let model = EfficiencyInput::paper(MTBF_12H, 320.0, 0.0, 0.0, 0.9).unwrap();
    let sim = TraceSim {
        trials: 3_000,
        seed: 5,
        shards: 4,
    };
    let scenario = |policy| TraceInput {
        model,
        policy,
        dist: FailureDist::Exponential,
        work: 20.0 * 86_400.0,
        interval: None,
    };
    let ec = sim
        .run(&scenario(RecoveryPolicy::EasyCrashPlusCheckpoint))
        .unwrap();
    let chk = sim.run(&scenario(RecoveryPolicy::CheckpointOnly)).unwrap();
    assert_eq!(ec.outcomes, chk.outcomes);
    assert_eq!(ec.mean_efficiency, chk.mean_efficiency);
    assert_eq!(ec.interval, chk.interval, "R=0 keeps the base Young interval");
    assert_eq!(ec.nvm_restarts, 0, "R=0 can never restart from NVM");
    assert!(ec.rollbacks > 0, "20 days at 12h MTBF must roll back");
}

/// Degenerate case 2: as MTBF → ∞ no failure ever lands inside the job
/// and the Young interval exceeds the job, so the only cost left is the
/// persistence overhead: efficiency → 1/(1+t_s) (exactly 1 for plain
/// C/R, which pays no t_s).
#[test]
fn infinite_mtbf_efficiency_approaches_one_over_one_plus_ts() {
    let ts = 0.03;
    let model = EfficiencyInput::paper(1e15, 320.0, 0.8, ts, 0.9).unwrap();
    let sim = TraceSim {
        trials: 200,
        seed: 1,
        shards: 2,
    };
    let scenario = |policy| TraceInput {
        model,
        policy,
        dist: FailureDist::Exponential,
        work: 86_400.0,
        interval: None,
    };
    for policy in [
        RecoveryPolicy::EasyCrashPlusCheckpoint,
        RecoveryPolicy::NvmRestartOnly,
    ] {
        let res = sim.run(&scenario(policy)).unwrap();
        assert_eq!(res.failures, 0, "{policy:?}");
        assert_eq!(res.checkpoints, 0, "{policy:?}: Young interval >> job");
        assert!(
            (res.mean_efficiency - 1.0 / (1.0 + ts)).abs() < 1e-12,
            "{policy:?}: {} vs {}",
            res.mean_efficiency,
            1.0 / (1.0 + ts)
        );
    }
    let res = sim.run(&scenario(RecoveryPolicy::CheckpointOnly)).unwrap();
    assert!((res.mean_efficiency - 1.0).abs() < 1e-12, "{}", res.mean_efficiency);
}

/// The paper's qualitative claim, statistically: at high recomputability
/// and expensive checkpoints, the simulated EasyCrash policy beats the
/// simulated plain C/R policy.
#[test]
fn easycrash_beats_checkpoint_only_at_high_recomputability() {
    let model = EfficiencyInput::paper(MTBF_12H, 3200.0, 0.85, 0.015, 0.9).unwrap();
    let sim = TraceSim {
        trials: 4_000,
        seed: 3,
        shards: 4,
    };
    let scenario = |policy| TraceInput {
        model,
        policy,
        dist: FailureDist::Exponential,
        work: 30.0 * 86_400.0,
        interval: None,
    };
    let base = sim.run(&scenario(RecoveryPolicy::CheckpointOnly)).unwrap();
    let ec = sim
        .run(&scenario(RecoveryPolicy::EasyCrashPlusCheckpoint))
        .unwrap();
    assert!(
        ec.mean_efficiency > base.mean_efficiency + 0.05,
        "EasyCrash must clearly win at R=0.85, T_chk=3200: {} vs {}",
        ec.mean_efficiency,
        base.mean_efficiency
    );
}

// -- the efficiency-trace cell type (spec -> Runner -> trace/v1 JSON) -------

#[test]
fn spec_trace_section_round_trips_and_validates() {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .trace(TraceSpec {
            trials: 1234,
            work: 1000.0,
            mtbf: 21_600.0,
            dist: FailureDist::Weibull { shape: 0.7 },
            t_r_nvm: 2.0,
        })
        .build()
        .unwrap();
    let back = ExperimentSpec::from_json(&spec.to_json().to_pretty()).unwrap();
    assert_eq!(back, spec);
    // A spec without a trace section stays trace-free through the round
    // trip (older spec files keep meaning exactly what they said).
    let plain = ExperimentSpec::builder().app("toy").build().unwrap();
    assert!(plain.trace.is_none());
    assert!(ExperimentSpec::from_json(&plain.to_json().to_string()).unwrap().trace.is_none());
    // Invalid trace sections are rejected at parse time.
    for bad in [
        r#"{"apps":["toy"],"trace":{"trials":0}}"#,
        r#"{"apps":["toy"],"trace":{"work":-5.0}}"#,
        r#"{"apps":["toy"],"trace":{"mtbf":0}}"#,
        r#"{"apps":["toy"],"trace":{"dist":"weibull:0"}}"#,
        r#"{"apps":["toy"],"trace":{"dist":"gauss"}}"#,
        r#"{"apps":["toy"],"trace":{"nope":1}}"#,
        r#"{"apps":["toy"],"trace":[1]}"#,
    ] {
        assert!(ExperimentSpec::from_json(bad).is_err(), "`{bad}` must be rejected");
    }
}

/// The `efficiency` subcommand's document: valid `easycrash.trace/v1`
/// JSON with one cell per (app, plan, T_chk), each carrying the
/// analytic and the simulated efficiencies — and the two agree loosely
/// even at smoke volume.
#[test]
fn efficiency_report_emits_valid_trace_v1_json() {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(20)
        .seed(3)
        .shards(2)
        .trace(TraceSpec {
            trials: 300,
            work: 10.0 * 86_400.0,
            ..Default::default()
        })
        .build()
        .unwrap();
    let runner = Runner::new(spec).unwrap();
    let report = runner.efficiency().unwrap();
    assert_eq!(report.cells.len(), 3, "1 app x 1 plan x 3 T_chk scenarios");

    let doc = Json::parse(&report.to_json().to_pretty()).expect("report JSON must parse");
    assert_eq!(
        doc.get("schema").and_then(Json::as_str),
        Some("easycrash.trace/v1")
    );
    assert!(doc.get("spec").is_some());
    assert_eq!(
        doc.get("trace").and_then(|t| t.get("trials")).and_then(Json::as_usize),
        Some(300)
    );
    let cells = doc.get("cells").and_then(Json::as_arr).expect("cells array");
    assert_eq!(cells.len(), 3);
    for cell in cells {
        for key in ["app", "plan", "plan_resolved", "r_measured", "t_chk", "analytic", "simulated"]
        {
            assert!(cell.get(key).is_some(), "cell is missing `{key}`");
        }
        let r = cell.get("r_measured").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&r));
        let analytic = cell.get("analytic").unwrap();
        let simulated = cell.get("simulated").unwrap();
        for side in ["base", "easycrash"] {
            let a = analytic.get(side).and_then(Json::as_f64).unwrap();
            let s = simulated
                .get(side)
                .and_then(|x| x.get("mean_efficiency"))
                .and_then(Json::as_f64)
                .unwrap();
            assert!(a > 0.0 && a <= 1.0, "{side}: analytic {a}");
            assert!(s > 0.0 && s <= 1.0, "{side}: simulated {s}");
            // Loose agreement at 300 trials; the 2% gate runs above.
            assert!((a - s).abs() < 0.05, "{side}: analytic {a} vs simulated {s}");
            for key in ["policy", "trials", "failures", "rollbacks", "nvm_restarts", "checkpoints"]
            {
                assert!(
                    simulated.get(side).and_then(|x| x.get(key)).is_some(),
                    "simulated.{side} is missing `{key}`"
                );
            }
        }
    }
}

//! End-to-end tests for `easycrash serve` + the `--server` client
//! (ISSUE §Server): a second identical job recomputes nothing, the
//! embedded report is byte-identical to a direct local run, concurrent
//! identical jobs single-flight each cell, a server restart over the
//! same store root serves from disk, and malformed jobs get a plain 400.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;

use easycrash::api::{ExperimentSpec, Runner};
use easycrash::server::{self, client, ServeConfig};
use easycrash::store::Store;
use easycrash::util::json::Json;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("easycrash-serve-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("test tmpdir");
    d
}

/// The 2-apps × 2-plans acceptance matrix, sized for test speed.
fn job_spec() -> ExperimentSpec {
    ExperimentSpec::builder()
        .apps(["toy", "is"])
        .plan_str("none")
        .and_then(|s| s.plan_str("all"))
        .expect("plans")
        .tests(10)
        .seed(0xEC)
        .build()
        .expect("job spec")
}

fn start_on(dir: &std::path::Path, store: Option<Store>) -> (server::ServerHandle, String) {
    let addr = format!("unix:{}", dir.join("serve.sock").display());
    let srv = server::start(ServeConfig {
        addr: addr.clone(),
        store,
        workers: 2,
        verbose: false,
    })
    .expect("server start");
    (srv, addr)
}

fn counts(done: &Json) -> (u64, u64, u64) {
    let n = |k| done.get(k).and_then(Json::as_u64).unwrap_or(u64::MAX);
    (n("memo_hits"), n("store_hits"), n("computed"))
}

fn report_pretty(done: &Json) -> String {
    done.get("report").expect("done carries the report").to_pretty()
}

#[test]
fn second_identical_job_recomputes_nothing_and_matches_a_local_run() {
    let dir = tmpdir("rerun");
    let (srv, addr) = start_on(&dir, None);
    let spec = job_spec();

    let mut cell_events = 0usize;
    let first = client::submit(&addr, &spec, |ev| {
        if ev.get("event").and_then(Json::as_str) == Some("cell") {
            cell_events += 1;
            assert!(ev.get("source").and_then(Json::as_str).is_some());
        }
    })
    .expect("first job");
    assert_eq!(cell_events, 4, "one cell event per matrix cell");
    let (_, _, computed) = counts(&first);
    assert_eq!(computed, 4, "a cold server simulates every cell");

    let second = client::submit(&addr, &spec, |_| {}).expect("second job");
    assert_eq!(counts(&second), (4, 0, 0), "warm job must be all memo hits");
    assert_eq!(
        report_pretty(&first),
        report_pretty(&second),
        "served reports must be byte-identical across submissions"
    );

    // Parity with a direct in-process run: the served document is the
    // same serialization the CLI writes with `--out`.
    let local = Runner::new(spec).unwrap().run().expect("local run");
    assert_eq!(
        report_pretty(&first),
        local.to_json().to_pretty(),
        "server must serve the exact local-run report document"
    );
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_identical_jobs_single_flight_every_cell() {
    let dir = tmpdir("flight");
    let (srv, addr) = start_on(&dir, None);
    let spec = job_spec();
    let (done_a, done_b) = std::thread::scope(|s| {
        let a = s.spawn(|| client::submit(&addr, &spec, |_| {}).expect("job a"));
        let b = s.spawn(|| client::submit(&addr, &spec, |_| {}).expect("job b"));
        (a.join().unwrap(), b.join().unwrap())
    });
    let (memo_a, _, computed_a) = counts(&done_a);
    let (memo_b, _, computed_b) = counts(&done_b);
    // Single-flight across concurrent jobs: each of the 4 cells is
    // simulated exactly once server-wide; the other job's request for
    // that cell is a memo hit (possibly a waiter on the in-flight one).
    assert_eq!(computed_a + computed_b, 4, "each cell simulates once");
    assert_eq!(memo_a + memo_b, 4, "the duplicate requests all hit");
    assert_eq!(report_pretty(&done_a), report_pretty(&done_b));
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn restarted_server_serves_from_the_store_without_recomputing() {
    let dir = tmpdir("restart");
    let store_root = dir.join("store");
    let spec = job_spec();

    let (srv, addr) = start_on(&dir, Some(Store::open(&store_root).unwrap()));
    let first = client::submit(&addr, &spec, |_| {}).expect("first job");
    assert_eq!(counts(&first).2, 4, "cold store: everything simulates");
    srv.stop(); // removes the socket file; the store root stays

    let (srv, addr) = start_on(&dir, Some(Store::open(&store_root).unwrap()));
    let second = client::submit(&addr, &spec, |_| {}).expect("job after restart");
    assert_eq!(
        counts(&second),
        (0, 4, 0),
        "a restarted server must serve every cell from the durable store"
    );
    assert_eq!(report_pretty(&first), report_pretty(&second));
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Raw-socket checks of the HTTP surface: health, stats, 400 on a
/// malformed job, 404 on an unknown route.
#[test]
fn http_surface_answers_health_stats_and_rejects_garbage() {
    let dir = tmpdir("http");
    let (srv, addr) = start_on(&dir, None);
    let sock = addr.strip_prefix("unix:").unwrap().to_string();
    let raw = |request: String| {
        let mut s = UnixStream::connect(&sock).expect("dial server");
        s.write_all(request.as_bytes()).unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        resp
    };

    let health = raw("GET /health HTTP/1.1\r\n\r\n".to_string());
    assert!(health.starts_with("HTTP/1.1 200 OK\r\n"), "got: {health}");
    assert!(health.ends_with("ok\n"));

    let stats = raw("GET /stats HTTP/1.1\r\n\r\n".to_string());
    assert!(stats.starts_with("HTTP/1.1 200 OK\r\n"));
    let body = stats.rsplit("\r\n\r\n").next().unwrap().trim();
    let j = Json::parse(body).expect("stats is JSON");
    assert!(j.get("computed").and_then(Json::as_u64).is_some());

    let body = "this is not a spec";
    let bad = raw(format!(
        "POST /jobs HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    ));
    assert!(bad.starts_with("HTTP/1.1 400 "), "got: {bad}");
    assert!(bad.contains("bad job spec"));

    let missing = raw("GET /nope HTTP/1.1\r\n\r\n".to_string());
    assert!(missing.starts_with("HTTP/1.1 404 "), "got: {missing}");

    // A chunked body cannot be framed by this server's Content-Length
    // subset: it must answer 400 with the reason, not read an empty body
    // and blame the spec.
    let chunked = raw(
        "POST /jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n\
         4\r\nspec\r\n0\r\n\r\n"
            .to_string(),
    );
    assert!(chunked.starts_with("HTTP/1.1 400 "), "got: {chunked}");
    assert!(
        chunked.contains("Transfer-Encoding (chunked) is not supported"),
        "got: {chunked}"
    );
    assert!(chunked.contains("Content-Length"), "got: {chunked}");

    // The client surfaces a rejected job as a typed error, not a hang.
    let invalid = ExperimentSpec::builder()
        .app("toy")
        .tests(10)
        .build()
        .unwrap();
    let mut broken = invalid;
    broken.apps = vec!["no-such-app".to_string()];
    let err = client::submit(&addr, &broken, |_| {}).unwrap_err();
    assert!(
        err.to_string().contains("server rejected job"),
        "got: {err}"
    );
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two concurrent jobs on a single-worker server: the pool's per-job
/// round-robin must give the small late job a slot after at most one
/// more of the big job's cells, instead of queueing it behind all of
/// them (a plain FIFO would finish the big job first — every one of
/// its cell events would land before the small job's `done`).
#[test]
fn late_small_job_interleaves_with_a_big_jobs_cells() {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Arc;

    let dir = tmpdir("fairness");
    let addr = format!("unix:{}", dir.join("serve.sock").display());
    let srv = server::start(ServeConfig {
        addr: addr.clone(),
        store: None,
        workers: 1, // serialize cells so dispatch order is observable
        verbose: false,
    })
    .expect("server start");

    // Big job: 4 cells, sized so they cannot all drain in the instant
    // between its `accepted` event and the small job's submission.
    let big = ExperimentSpec::builder()
        .apps(["toy", "is"])
        .plan_str("none")
        .and_then(|s| s.plan_str("all"))
        .expect("plans")
        .tests(400)
        .seed(0xEC)
        .build()
        .expect("big spec");
    // Small job: one cheap cell with a different key than any big cell.
    let small = ExperimentSpec::builder()
        .app("toy")
        .plan_str("none")
        .expect("plan")
        .tests(5)
        .seed(0xEC)
        .build()
        .expect("small spec");

    let big_accepted = Arc::new(AtomicBool::new(false));
    let big_cells_done = Arc::new(AtomicUsize::new(0));
    let cells_when_small_finished = std::thread::scope(|s| {
        let accepted = big_accepted.clone();
        let cells = big_cells_done.clone();
        let addr_big = addr.clone();
        let big_job = s.spawn(move || {
            client::submit(&addr_big, &big, |ev| {
                match ev.get("event").and_then(Json::as_str) {
                    Some("accepted") => accepted.store(true, Ordering::SeqCst),
                    Some("cell") => {
                        cells.fetch_add(1, Ordering::SeqCst);
                    }
                    _ => {}
                }
            })
            .expect("big job")
        });
        // Submit the small job only once the big one holds the queue.
        while !big_accepted.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        client::submit(&addr, &small, |_| {}).expect("small job");
        let snapshot = big_cells_done.load(Ordering::SeqCst);
        big_job.join().unwrap();
        snapshot
    });
    assert!(
        cells_when_small_finished < 4,
        "small job finished only after all {cells_when_small_finished} big cells — \
         the pool queued it FIFO instead of interleaving jobs"
    );
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A `--ranks > 1` job announces its rank topology as a dedicated
/// `ranks` event before any cell completes, and the embedded report's
/// spec round-trips the ranks/recovery axis.
#[test]
fn multi_rank_job_streams_a_ranks_event() {
    let dir = tmpdir("ranks");
    let (srv, addr) = start_on(&dir, None);
    let spec = ExperimentSpec::builder()
        .app("dcg")
        .plan_str("none")
        .expect("plan")
        .tests(6)
        .seed(0xEC)
        .ranks(4)
        .recovery(easycrash::easycrash::RecoveryMode::Assisted)
        .build()
        .expect("rank spec");

    let mut events = Vec::new();
    let done = client::submit(&addr, &spec, |ev| {
        if let Some(kind) = ev.get("event").and_then(Json::as_str) {
            events.push((kind.to_string(), ev.clone()));
        }
    })
    .expect("rank job");

    let ranks_pos = events.iter().position(|(k, _)| k == "ranks");
    let first_cell = events.iter().position(|(k, _)| k == "cell");
    let (pos, ev) = ranks_pos
        .map(|p| (p, &events[p].1))
        .expect("stream carries a ranks event");
    assert!(pos < first_cell.expect("job has cells"), "ranks precedes cells");
    assert_eq!(ev.get("ranks").and_then(Json::as_u64), Some(4));
    assert_eq!(ev.get("recovery").and_then(Json::as_str), Some("assisted"));
    let report_spec = done.get("report").and_then(|r| r.get("spec")).expect("spec");
    assert_eq!(report_spec.get("ranks").and_then(Json::as_u64), Some(4));
    assert_eq!(
        report_spec.get("recovery").and_then(Json::as_str),
        Some("assisted")
    );
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A `--sampler classes` job streams one `easycrash.coverage/v1` event
/// per cell alongside the cell events, and the client's event loop
/// tolerates (and surfaces) them.
#[test]
fn classes_job_streams_coverage_events() {
    let dir = tmpdir("coverage");
    let (srv, addr) = start_on(&dir, None);
    let spec = ExperimentSpec::builder()
        .app("toy")
        .plan_str("all")
        .expect("plan")
        .tests(10)
        .seed(0xEC)
        .sampler_str("classes")
        .expect("sampler")
        .build()
        .expect("spec");

    let mut coverage_events = Vec::new();
    let done = client::submit(&addr, &spec, |ev| {
        if ev.get("event").and_then(Json::as_str) == Some("coverage") {
            coverage_events.push(ev.clone());
        }
    })
    .expect("classes job");

    assert_eq!(coverage_events.len(), 1, "one coverage event per cell");
    let cov = coverage_events[0].get("coverage").expect("coverage payload");
    assert_eq!(
        cov.get("schema").and_then(Json::as_str),
        Some("easycrash.coverage/v1")
    );
    assert!(cov.get("classes_total").and_then(Json::as_u64).unwrap() > 0);
    // The embedded report carries the same coverage block.
    let report = done.get("report").expect("report");
    let cell = &report.get("cells").and_then(Json::as_arr).expect("cells")[0];
    assert_eq!(
        cell.get("coverage").and_then(|c| c.get("schema")).and_then(Json::as_str),
        Some("easycrash.coverage/v1")
    );
    srv.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

//! Robustness and durability tests for `easycrash::store` (ISSUE §Store):
//! bit-identical round-trips, the typed-miss corruption matrix (every
//! damaged entry classifies — nothing panics, everything recomputes and
//! repairs), concurrent same-key writers, and cross-process read-through
//! at the `Runner` level (second process recomputes nothing and emits a
//! byte-identical report).

use std::path::PathBuf;
use std::sync::Arc;

use easycrash::api::{ExperimentSpec, Runner};
use easycrash::apps;
use easycrash::easycrash::{CampaignResult, PersistPlan};
use easycrash::store::codec::{decode_result, encode_result, results_bit_identical};
use easycrash::store::{CellCache, CellKey, Lookup, Store, StoreMiss, STORE_VERSION};

/// Fresh per-test scratch dir (tests in one binary run concurrently).
fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("easycrash-store-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("test tmpdir");
    d
}

/// One real computed toy campaign cell + its canonical key.
fn toy_cell() -> (CampaignResult, CellKey, ExperimentSpec) {
    let spec = ExperimentSpec::builder()
        .app("toy")
        .tests(16)
        .seed(7)
        .build()
        .expect("toy spec");
    let runner = Runner::new(spec.clone()).expect("runner");
    let app = apps::by_name("toy").unwrap();
    let plan = PersistPlan::none();
    let res = runner
        .execute_cell(app.as_ref(), &plan, false)
        .expect("toy campaign");
    let key = CellKey::campaign(
        "toy",
        &plan.dsl(),
        false,
        spec.tests,
        spec.seed,
        "uniform",
        "native",
        1,
        "global",
        &spec.cfg,
    );
    (res, key, spec)
}

/// Same FNV-1a as `sim::pool` / the store (reimplemented here so the
/// tests can forge whole entries, checksum included, from outside the
/// crate).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

fn load_miss(store: &Store, key: &CellKey) -> StoreMiss {
    match store.load(key) {
        Lookup::Miss(m) => m,
        Lookup::Hit(_) => panic!("expected a typed miss, got a hit"),
    }
}

#[test]
fn codec_round_trip_is_bit_identical() {
    let (res, _, _) = toy_cell();
    let bytes = encode_result(&res);
    let back = decode_result(&bytes).expect("decode freshly encoded result");
    assert!(
        results_bit_identical(&res, &back),
        "codec round-trip must preserve every field bit-for-bit"
    );
}

#[test]
fn store_round_trip_is_bit_identical_and_misses_are_cold() {
    let dir = tmpdir("roundtrip");
    let (res, key, _) = toy_cell();
    let store = Store::open(&dir).unwrap();
    assert_eq!(load_miss(&store, &key), StoreMiss::NotFound);
    let path = store.save(&key, &res).unwrap();
    assert_eq!(path, store.entry_path(&key));
    match store.load(&key) {
        Lookup::Hit(back) => assert!(results_bit_identical(&res, &back)),
        Lookup::Miss(m) => panic!("expected hit after save, got {m}"),
    }
    // No stray temp files after a clean publish.
    let stray: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
        .collect();
    assert!(stray.is_empty(), "temp files must not outlive a save");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The corruption matrix: each damaged shape classifies as its typed
/// miss — never a panic, never wrong data.
#[test]
fn damaged_entries_classify_as_typed_misses() {
    let dir = tmpdir("corrupt");
    let (res, key, spec) = toy_cell();
    let store = Store::open(&dir).unwrap();
    store.save(&key, &res).unwrap();
    let path = store.entry_path(&key);
    let good = std::fs::read(&path).unwrap();

    let with = |bytes: &[u8], f: &mut dyn FnMut(&mut Vec<u8>)| {
        let mut b = bytes.to_vec();
        f(&mut b);
        std::fs::write(&path, &b).unwrap();
        load_miss(&store, &key)
    };

    // Shorter than the magic itself.
    assert_eq!(
        with(&good, &mut |b| b.truncate(3)),
        StoreMiss::TruncatedEntry
    );
    // Magic intact but the fixed frame is cut off (a torn copy).
    assert_eq!(
        with(&good, &mut |b| b.truncate(10)),
        StoreMiss::TruncatedEntry
    );
    // Not a store entry at all.
    assert_eq!(
        with(&good, &mut |b| b[..4].copy_from_slice(b"NOPE")),
        StoreMiss::BadMagic
    );
    // Version skew is detected before the checksum, so a bare version
    // patch classifies (no forged checksum needed).
    assert_eq!(
        with(&good, &mut |b| b[4..12]
            .copy_from_slice(&(STORE_VERSION + 1).to_le_bytes())),
        StoreMiss::VersionSkew {
            found: STORE_VERSION + 1
        }
    );
    // One flipped payload bit: whole-entry checksum catches it.
    assert_eq!(
        with(&good, &mut |b| {
            let mid = b.len() - 16; // inside the payload, before the checksum
            b[mid] ^= 0x01;
        }),
        StoreMiss::BadChecksum
    );
    // Truncated *and* re-checksummed == still truncated framing.
    assert_eq!(
        with(&good, &mut |b| {
            b.truncate(40);
            let sum = fnv1a64(&b[..32]);
            b[32..40].copy_from_slice(&sum.to_le_bytes());
        }),
        StoreMiss::TruncatedEntry
    );
    // A perfectly framed entry whose payload the codec rejects.
    let forged = with(&good, &mut |b| {
        b.clear();
        b.extend_from_slice(b"ECST");
        b.extend_from_slice(&STORE_VERSION.to_le_bytes());
        b.extend_from_slice(&key.hash().to_le_bytes());
        let k = key.canonical().as_bytes();
        b.extend_from_slice(&(k.len() as u64).to_le_bytes());
        b.extend_from_slice(k);
        let garbage = [0xFFu8; 16];
        b.extend_from_slice(&(garbage.len() as u64).to_le_bytes());
        b.extend_from_slice(&garbage);
        let sum = fnv1a64(b);
        b.extend_from_slice(&sum.to_le_bytes());
    });
    assert!(
        matches!(forged, StoreMiss::Undecodable(_)),
        "forged payload must classify as Undecodable, got {forged}"
    );

    // An entry legitimately written under a *different* key, landed on
    // this key's path (hash collision stand-in): typed mismatch, never
    // the wrong cell's data.
    let other = CellKey::campaign(
        "toy", "none", false, 999, 7, "uniform", "native", 1, "global", &spec.cfg,
    );
    store.save(&other, &res).unwrap();
    std::fs::copy(store.entry_path(&other), &path).unwrap();
    assert_eq!(load_miss(&store, &key), StoreMiss::KeyMismatch);

    // Restore the good bytes: loads cleanly again (damage was all ours).
    std::fs::write(&path, &good).unwrap();
    assert!(matches!(store.load(&key), Lookup::Hit(_)));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A damaged entry behind the cache recomputes (counted as a store
/// error) and the write-back repairs the entry on disk.
#[test]
fn cache_recomputes_and_repairs_damaged_entries() {
    let dir = tmpdir("repair");
    let (res, key, _) = toy_cell();
    {
        let store = Store::open(&dir).unwrap();
        store.save(&key, &res).unwrap();
        // Damage it: flip one payload byte.
        let path = store.entry_path(&key);
        let mut b = std::fs::read(&path).unwrap();
        let mid = b.len() - 16;
        b[mid] ^= 0x01;
        std::fs::write(&path, &b).unwrap();
    }
    let cache = CellCache::new(Some(Store::open(&dir).unwrap()));
    let (served, source) = cache
        .get_or_compute(&key, || Ok(res.clone()))
        .expect("recompute through damaged entry");
    assert_eq!(source.label(), "computed");
    assert!(results_bit_identical(&served, &res));
    let s = cache.stats();
    assert_eq!((s.computed, s.store_hits, s.store_errors), (1, 0, 1));
    // The write-back repaired the entry for the next process.
    match Store::open(&dir).unwrap().load(&key) {
        Lookup::Hit(back) => assert!(results_bit_identical(&back, &res)),
        Lookup::Miss(m) => panic!("entry not repaired: {m}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Racing same-key writers (atomic tmp + rename) always leave one
/// complete, valid entry — results are deterministic per key, so last
/// rename winning is indistinguishable from any other winner.
#[test]
fn concurrent_writers_publish_atomically() {
    let dir = tmpdir("race");
    let (res, key, _) = toy_cell();
    let res = Arc::new(res);
    std::thread::scope(|s| {
        for _ in 0..8 {
            let store = Store::open(&dir).unwrap();
            let (key, res) = (&key, Arc::clone(&res));
            s.spawn(move || {
                for _ in 0..20 {
                    store.save(key, &res).unwrap();
                }
            });
        }
        // A racing reader must only ever see NotFound or a complete entry.
        let store = Store::open(&dir).unwrap();
        let key = &key;
        s.spawn(move || {
            for _ in 0..100 {
                match store.load(key) {
                    Lookup::Hit(_) | Lookup::Miss(StoreMiss::NotFound) => {}
                    Lookup::Miss(m) => panic!("reader observed a torn entry: {m}"),
                }
            }
        });
    });
    match Store::open(&dir).unwrap().load(&key) {
        Lookup::Hit(back) => assert!(results_bit_identical(&back, &res)),
        Lookup::Miss(m) => panic!("expected hit after the race, got {m}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance check: process A computes a 2-apps × 2-plans matrix
/// against a store; a fresh process (stand-in: a fresh `Runner` +
/// `Store` on the same root) replays the spec with **zero** campaign
/// recomputation and a byte-identical report document.
#[test]
fn second_process_recomputes_nothing_and_reports_identically() {
    let dir = tmpdir("crossproc");
    let spec = ExperimentSpec::builder()
        .apps(["toy", "is"])
        .plan_str("none")
        .and_then(|s| s.plan_str("all"))
        .expect("plans")
        .tests(12)
        .seed(0xEC)
        .build()
        .expect("spec");

    let runner_a = Runner::new(spec.clone())
        .unwrap()
        .with_store(Some(Store::open(&dir).unwrap()));
    let report_a = runner_a.run().expect("first run").to_json().to_pretty();
    assert!(runner_a.cache().stats().computed > 0, "first run simulates");

    let runner_b = Runner::new(spec)
        .unwrap()
        .with_store(Some(Store::open(&dir).unwrap()));
    let report_b = runner_b.run().expect("second run").to_json().to_pretty();
    let s = runner_b.cache().stats();
    assert_eq!(s.computed, 0, "second process must recompute nothing");
    assert!(s.store_hits >= 4, "all 4 campaign cells served from disk");
    assert_eq!(report_a, report_b, "report documents must be byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `Store::open` sweeps temp files abandoned by dead writers — and only
/// those: temps of live writers (any pid still in `/proc`, plus our own
/// in-flight ones) and published entries survive untouched.
#[test]
fn open_sweeps_stale_temp_files_from_dead_writers() {
    let dir = tmpdir("sweep");
    let (res, key, _) = toy_cell();
    Store::open(&dir).unwrap().save(&key, &res).unwrap();

    // A writer killed between the temp write and the rename leaves
    // exactly this shape behind. pid_max caps real pids at 2^22, so
    // u32::MAX can never name a live process.
    let dead = dir.join(format!("{}.tmp.{}.7", key.file_name(), u32::MAX));
    let live = dir.join(format!("{}.tmp.1.0", key.file_name())); // pid 1
    let own = dir.join(format!("{}.tmp.{}.3", key.file_name(), std::process::id()));
    let not_tmp = dir.join("README.txt");
    for p in [&dead, &live, &own, &not_tmp] {
        std::fs::write(p, b"abandoned").unwrap();
    }

    let store = Store::open(&dir).unwrap();
    if std::path::Path::new("/proc").is_dir() {
        assert!(!dead.exists(), "a dead writer's temp file must be swept");
    }
    assert!(live.exists(), "a live writer's temp file must be spared");
    assert!(own.exists(), "our own in-flight temp files must be spared");
    assert!(not_tmp.exists(), "non-temp files are never touched");
    match store.load(&key) {
        Lookup::Hit(back) => assert!(results_bit_identical(&back, &res)),
        Lookup::Miss(m) => panic!("published entry must survive the sweep: {m}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--store-dir` style relocation: the store root is just a directory —
/// moving it wholesale keeps every entry valid (names and checksums are
/// root-relative).
#[test]
fn store_root_is_relocatable() {
    let dir = tmpdir("reloc");
    let (res, key, _) = toy_cell();
    let a = dir.join("a");
    let b = dir.join("b");
    Store::open(&a).unwrap().save(&key, &res).unwrap();
    std::fs::rename(&a, &b).unwrap();
    match Store::open(&b).unwrap().load(&key) {
        Lookup::Hit(back) => assert!(results_bit_identical(&back, &res)),
        Lookup::Miss(m) => panic!("relocated store must still hit: {m}"),
    }
    assert!(!a.exists());
    let _ = std::fs::remove_dir_all(&dir);
}

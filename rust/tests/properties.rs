//! Property-based tests (mini-quickcheck) on the simulator's invariants —
//! the correctness bedrock of every figure in the reproduction.

use easycrash::prop_assert;
use easycrash::sim::{
    CacheGeom, Env, FlushKind, Hierarchy, Memory, ObjSpec, RawEnv, SimConfig, SimEnv,
};
use easycrash::util::quickcheck::{check, Gen};

fn random_cfg(g: &mut Gen) -> SimConfig {
    // Random small power-of-two geometries.
    let l1_sets = 1usize << g.size(2, 4);
    let l2_sets = 1usize << g.size(3, 5);
    let l3_sets = 1usize << g.size(4, 6);
    SimConfig {
        l1: CacheGeom::new(l1_sets * 2 * 64, 2),
        l2: CacheGeom::new(l2_sets * 4 * 64, 4),
        l3: CacheGeom::new(l3_sets * 8 * 64, 8),
        nvm: easycrash::sim::NvmProfile::DRAM,
        snapshot_every: None,
    }
}

/// The dual-image invariant: arch and nvm may differ ONLY on lines that
/// are currently dirty somewhere in the hierarchy.
#[test]
fn prop_divergence_only_on_dirty_lines() {
    check(0xD1, 30, |g| {
        let cfg = random_cfg(g);
        let mut h = Hierarchy::new(&cfg);
        let span = 64 * g.size(64, 256);
        let mut m = Memory::new(span);
        for _ in 0..g.size(200, 3000) {
            let addr = g.size(0, span / 8 - 1) * 8;
            let write = g.bool(0.4);
            if write {
                m.st_f64(addr, g.f64(-1e6, 1e6));
            }
            h.access(&mut m, addr, write);
        }
        let dirty: std::collections::HashSet<u64> = h.dirty_lines().into_iter().collect();
        for line in 0..(span / 64) as u64 {
            let off = line as usize * 64;
            let divergent = m.divergent_bytes(off, 64) > 0;
            if divergent {
                prop_assert!(
                    dirty.contains(&line),
                    "line {line} divergent but not dirty anywhere"
                );
            }
        }
        Ok(())
    });
}

/// After flushing everything, the images are identical and nothing is
/// dirty — regardless of access history or flush kind.
#[test]
fn prop_full_flush_synchronizes_images() {
    check(0xD2, 30, |g| {
        let cfg = random_cfg(g);
        let mut h = Hierarchy::new(&cfg);
        let span = 64 * g.size(32, 128);
        let mut m = Memory::new(span);
        for _ in 0..g.size(100, 2000) {
            let addr = g.size(0, span / 8 - 1) * 8;
            let write = g.bool(0.5);
            if write {
                m.st_f64(addr, g.f64(-1.0, 1.0));
            }
            h.access(&mut m, addr, write);
        }
        let kind = if g.bool(0.5) {
            FlushKind::Clwb
        } else {
            FlushKind::ClflushOpt
        };
        h.flush_range(&mut m, 0, span, kind);
        prop_assert!(
            m.divergent_bytes(0, span) == 0,
            "images must match after full flush"
        );
        prop_assert!(h.dirty_lines().is_empty(), "no dirty lines after flush");
        Ok(())
    });
}

/// SimEnv and RawEnv observe identical values for identical programs
/// (the simulator never corrupts program semantics).
#[test]
fn prop_sim_equals_raw_semantics() {
    check(0xD3, 20, |g| {
        let cfg = random_cfg(g);
        let n = g.size(16, 200);
        let mut sim = SimEnv::new(&cfg, 1);
        let mut raw = RawEnv::new();
        let bs = sim.alloc(ObjSpec::f64("x", n, true));
        let br = raw.alloc(ObjSpec::f64("x", n, true));
        // Random program: interleaved loads/stores with value dependences.
        let mut acc_s = 0.0f64;
        let mut acc_r = 0.0f64;
        for _ in 0..g.size(100, 1500) {
            let i = g.size(0, n - 1);
            if g.bool(0.5) {
                let v = g.f64(-10.0, 10.0) + acc_s * 0.25;
                sim.st(bs, i, v).unwrap();
                let vr = g.f64(-10.0, 10.0); // consume same rng draws? no —
                let _ = vr; // keep streams aligned by drawing identically:
                raw.st(br, i, v).unwrap();
            } else {
                acc_s += sim.ld(bs, i).unwrap();
                acc_r += raw.ld(br, i).unwrap();
            }
        }
        prop_assert!(acc_s == acc_r, "sim {acc_s} vs raw {acc_r}");
        for i in 0..n {
            let a = sim.ld(bs, i).unwrap();
            let b = raw.ld(br, i).unwrap();
            prop_assert!(a == b, "x[{i}]: sim {a} vs raw {b}");
        }
        Ok(())
    });
}

/// NVM writes only grow, and flushing a range makes exactly that range's
/// object bytes persistent.
#[test]
fn prop_flush_persists_target_range() {
    check(0xD4, 30, |g| {
        let cfg = random_cfg(g);
        let mut h = Hierarchy::new(&cfg);
        let lines = g.size(16, 64);
        let span = lines * 64;
        let mut m = Memory::new(span);
        for l in 0..lines {
            m.st_f64(l * 64, l as f64 + 0.5);
            h.access(&mut m, l * 64, true);
        }
        let w_before = h.stats.nvm_writes();
        let lo = g.size(0, lines - 1);
        let hi = g.size(lo, lines - 1);
        h.flush_range(&mut m, lo * 64, (hi - lo + 1) * 64, FlushKind::ClflushOpt);
        prop_assert!(h.stats.nvm_writes() >= w_before, "write counter monotone");
        for l in lo..=hi {
            prop_assert!(
                m.divergent_bytes(l * 64, 64) == 0,
                "flushed line {l} must be persistent"
            );
        }
        Ok(())
    });
}

/// Inconsistent rate is within [0,1] and zero exactly when object bytes
/// match between the images.
#[test]
fn prop_inconsistent_rate_bounds() {
    check(0xD5, 20, |g| {
        let cfg = random_cfg(g);
        let n = g.size(8, 512);
        let mut sim = SimEnv::new(&cfg, 1);
        let b = sim.alloc(ObjSpec::f64("x", n, true));
        for _ in 0..g.size(50, 800) {
            let i = g.size(0, n - 1);
            sim.st(b, i, g.f64(-5.0, 5.0)).unwrap();
        }
        let rate = sim.inconsistent_rate(b.id);
        prop_assert!((0.0..=1.0).contains(&rate), "rate {rate}");
        // Drain -> rate must become exactly 0.
        sim.hier.drain(&mut sim.mem);
        let rate2 = sim.inconsistent_rate(b.id);
        prop_assert!(rate2 == 0.0, "post-drain rate {rate2}");
        Ok(())
    });
}

/// The knapsack never exceeds its budget and never selects value-free
/// regions.
#[test]
fn prop_knapsack_respects_budget() {
    use easycrash::easycrash::regions::{select_regions, RegionModel};
    check(0xD6, 60, |g| {
        let w = g.size(1, 16);
        let mut m = RegionModel {
            a: Vec::new(),
            c: Vec::new(),
            cmax: Vec::new(),
            l: Vec::new(),
            is_loop: Vec::new(),
        };
        for _ in 0..w {
            let c = g.f64(0.0, 1.0);
            m.a.push(g.f64(0.0, 1.0));
            m.c.push(c);
            m.cmax.push((c + g.f64(0.0, 1.0 - c)).min(1.0));
            m.l.push(g.f64(0.001, 0.08));
            m.is_loop.push(g.bool(0.7));
        }
        let ts = g.f64(0.005, 0.06);
        let sel = select_regions(&m, ts, 0.0);
        prop_assert!(
            sel.predicted_overhead <= ts + 1e-9,
            "overhead {} > budget {ts}",
            sel.predicted_overhead
        );
        for ch in &sel.choices {
            prop_assert!(ch.region < w, "region index in range");
            prop_assert!(ch.x >= 1, "x >= 1");
            let gain = m.a[ch.region] * (m.cmax[ch.region] - m.c[ch.region]);
            prop_assert!(gain > 0.0, "chosen region must have positive gain");
        }
        Ok(())
    });
}

/// §7 analytic model: under the paper's parameter coupling
/// (T_r = T_chk, T_sync = T_chk/2) and any sane regime, both
/// efficiencies live in (0, 1], grow (weakly) with MTBF and shrink
/// (weakly) with T_chk — the shape every figure and the Monte Carlo
/// validation rely on.
#[test]
fn prop_efficiency_bounds_and_monotonicity() {
    use easycrash::model::efficiency::{evaluate, EfficiencyInput};
    check(0xD8, 80, |g| {
        let t_chk = g.f64(5.0, 2000.0);
        // Keep 4x the checkpoint cost well under the MTBF so the model
        // stays out of its saturated (efficiency 0) corner.
        let mtbf = g.f64(t_chk * 20.0, t_chk * 2000.0);
        let r = g.f64(0.0, 1.0);
        let ts = g.f64(0.001, 0.05);
        let t_r_nvm = g.f64(0.0, 30.0);
        let point = |mtbf: f64, t_chk: f64| {
            evaluate(&EfficiencyInput::paper(mtbf, t_chk, r, ts, t_r_nvm).unwrap()).unwrap()
        };
        let m = point(mtbf, t_chk);
        prop_assert!(m.base > 0.0 && m.base <= 1.0, "base {}", m.base);
        prop_assert!(
            m.easycrash > 0.0 && m.easycrash <= 1.0,
            "easycrash {}",
            m.easycrash
        );
        // Monotone non-decreasing in MTBF.
        let better = point(mtbf * g.f64(1.1, 4.0), t_chk);
        prop_assert!(better.base >= m.base - 1e-12, "{} < {}", better.base, m.base);
        prop_assert!(
            better.easycrash >= m.easycrash - 1e-12,
            "{} < {}",
            better.easycrash,
            m.easycrash
        );
        // Monotone non-increasing in T_chk.
        let worse = point(mtbf, t_chk * g.f64(1.1, 4.0));
        prop_assert!(worse.base <= m.base + 1e-12, "{} > {}", worse.base, m.base);
        prop_assert!(
            worse.easycrash <= m.easycrash + 1e-12,
            "{} > {}",
            worse.easycrash,
            m.easycrash
        );
        Ok(())
    });
}

/// `EfficiencyInput` validation rejects NaN and non-positive inputs via
/// `util::error::Error` — no `assert!` panics anywhere on the path.
#[test]
fn model_validation_rejects_bad_inputs_via_error() {
    use easycrash::model::efficiency::{evaluate, tau_threshold, EfficiencyInput};
    use easycrash::model::young_interval;
    // young_interval: the old implementation panicked here.
    assert!(young_interval(0.0, 43_200.0).is_err());
    assert!(young_interval(-32.0, 43_200.0).is_err());
    assert!(young_interval(32.0, -1.0).is_err());
    assert!(young_interval(f64::NAN, 43_200.0).is_err());
    assert!(young_interval(32.0, f64::NAN).is_err());
    assert!(young_interval(f64::INFINITY, 43_200.0).is_err());
    assert!(young_interval(32.0, 43_200.0).is_ok());
    // EfficiencyInput::paper funnels through validate().
    assert!(EfficiencyInput::paper(f64::NAN, 320.0, 0.5, 0.015, 0.9).is_err());
    assert!(EfficiencyInput::paper(0.0, 320.0, 0.5, 0.015, 0.9).is_err());
    assert!(EfficiencyInput::paper(43_200.0, 0.0, 0.5, 0.015, 0.9).is_err());
    assert!(EfficiencyInput::paper(43_200.0, f64::NAN, 0.5, 0.015, 0.9).is_err());
    assert!(EfficiencyInput::paper(43_200.0, 320.0, -0.1, 0.015, 0.9).is_err());
    assert!(EfficiencyInput::paper(43_200.0, 320.0, 1.1, 0.015, 0.9).is_err());
    assert!(EfficiencyInput::paper(43_200.0, 320.0, f64::NAN, 0.015, 0.9).is_err());
    assert!(EfficiencyInput::paper(43_200.0, 320.0, 0.5, -0.01, 0.9).is_err());
    assert!(EfficiencyInput::paper(43_200.0, 320.0, 0.5, 0.015, f64::NAN).is_err());
    // Hand-built structs with poisoned fields fail at evaluate /
    // tau_threshold instead of propagating NaN into figures.
    let mut bad = EfficiencyInput::paper(43_200.0, 320.0, 0.5, 0.015, 0.9).unwrap();
    bad.t_r = f64::NAN;
    assert!(evaluate(&bad).is_err());
    assert!(tau_threshold(&bad).is_err());
    let mut bad = EfficiencyInput::paper(43_200.0, 320.0, 0.5, 0.015, 0.9).unwrap();
    bad.t_sync = -1.0;
    assert!(evaluate(&bad).is_err());
    // Boundary values are fine: zero overheads, R at both ends.
    assert!(EfficiencyInput::paper(43_200.0, 320.0, 0.0, 0.0, 0.0).is_ok());
    assert!(EfficiencyInput::paper(43_200.0, 320.0, 1.0, 0.0, 0.0).is_ok());
}

/// Spearman is symmetric in rank transformations and bounded.
#[test]
fn prop_spearman_bounds_and_monotone_invariance() {
    use easycrash::easycrash::stats::spearman;
    check(0xD7, 50, |g| {
        let n = g.size(8, 200);
        let xs = g.vec_f64(n, -100.0, 100.0);
        let ys = g.vec_f64(n, -100.0, 100.0);
        let c = spearman(&xs, &ys);
        prop_assert!((-1.0..=1.0).contains(&c.rs), "rs {}", c.rs);
        prop_assert!((0.0..=1.0).contains(&c.p), "p {}", c.p);
        // Monotone transform of x must not change rs.
        let xs2: Vec<f64> = xs.iter().map(|x| x.exp().min(1e300)).collect();
        let c2 = spearman(&xs2, &ys);
        prop_assert!(
            (c.rs - c2.rs).abs() < 1e-9,
            "monotone invariance: {} vs {}",
            c.rs,
            c2.rs
        );
        Ok(())
    });
}
